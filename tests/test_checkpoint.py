"""Checkpointing: roundtrip, checksums, atomicity, GC, async, restart,
the shard/manifest format layer, and resharded (N writers -> M readers)
restore simulated without extra processes (the real multi-process drills
live in tests/test_distrib.py)."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import format as ckfmt
from repro.checkpoint.checkpoint import CheckpointManager
from repro.checkpoint.format import CheckpointCorruptError
from repro.core.futures import FuturizedGraph


def _tree(key=0):
    k = jax.random.PRNGKey(key)
    return {"w": jax.random.normal(k, (8, 16)),
            "nested": {"b": jnp.arange(5, dtype=jnp.int32),
                       "s": jnp.float32(3.5)}}


def _boom():
    raise RuntimeError("boom: injected dependency failure")


def test_roundtrip(tmp_path):
    cm = CheckpointManager(tmp_path, async_save=False)
    t = _tree()
    cm.save(10, t, meta={"note": "hi"})
    step, back = cm.restore(t)
    assert step == 10
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert cm.meta["note"] == "hi"


def test_async_save_then_restore(tmp_path):
    cm = CheckpointManager(tmp_path, async_save=True)
    t = _tree(1)
    cm.save(3, t)
    cm.wait()
    step, back = cm.restore(t)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(back["w"]), np.asarray(t["w"]))


def test_checksum_corruption_detected_and_names_shard(tmp_path):
    cm = CheckpointManager(tmp_path, async_save=False)
    t = _tree(2)
    path = cm.save(1, t)
    # flip a byte in the shard file's leaf data
    f = next(path.glob("shard_*.bin"))
    raw = bytearray(f.read_bytes())
    raw[-1] ^= 0xFF
    f.write_bytes(bytes(raw))
    with pytest.raises(CheckpointCorruptError, match="shard_00000.bin"):
        cm.restore(t)
    # non-strict mode loads anyway
    step, _ = cm.restore(t, strict_checksums=False)
    assert step == 1


def test_gc_keeps_last_k(tmp_path):
    cm = CheckpointManager(tmp_path, keep=2, async_save=False)
    t = _tree(3)
    for s in (1, 2, 3, 4):
        cm.save(s, t)
    assert cm.all_steps() == [3, 4]


def test_leaf_count_mismatch_raises(tmp_path):
    cm = CheckpointManager(tmp_path, async_save=False)
    cm.save(1, _tree(4))
    with pytest.raises(ValueError, match="leaves"):
        cm.restore({"only": jnp.zeros(3)})


# -- format layer -------------------------------------------------------------

def test_assign_shards_contiguous_and_balanced():
    assert ckfmt.assign_shards(5, [0, 1, 2]) == [
        (0, 0, [0, 1]), (1, 1, [2, 3]), (2, 2, [4])]
    # fewer leaves than ranks: empty shards are dropped
    assert ckfmt.assign_shards(2, [0, 1, 2]) == [(0, 0, [0]), (1, 1, [1])]
    assert ckfmt.assign_shards(3, [0]) == [(0, 0, [0, 1, 2])]


def test_manifest_schema_and_ownership_single_process(tmp_path):
    cm = CheckpointManager(tmp_path, async_save=False)
    path = cm.save(2, _tree())
    m = json.loads((path / "manifest.json").read_text())
    assert m["format"] == ckfmt.FORMAT_VERSION
    assert m["n_shards"] == 1 and m["ownership"] == {"0": [0]}
    assert m["n_leaves"] == 3
    # shards cover exactly the global leaf indices, in order
    covered = [leaf["index"] for s in m["shards"] for leaf in s["leaves"]]
    assert covered == [0, 1, 2]
    for s in m["shards"]:
        assert s["checksum"] == ckfmt.shard_checksum(
            leaf["checksum"] for leaf in s["leaves"])


def _write_two_shard_checkpoint(tmp_path, t, step=7):
    """Simulate an N=2 save through the format layer alone."""
    leaves, treedef = jax.tree.flatten(t)
    host = [np.asarray(x) for x in leaves]
    shards = ckfmt.assign_shards(len(host), [0, 1])
    assert len(shards) == 2
    tmp = tmp_path / f".tmp_step_{step:08d}"
    entries = [ckfmt.save_shard(str(tmp), sid, idx, [host[i] for i in idx])
               for sid, _rank, idx in shards]
    manifest = ckfmt.build_manifest(step=step, treedef=str(treedef),
                                    n_leaves=len(host), shards=entries)
    return ckfmt.commit_manifest(tmp, tmp_path / f"step_{step:08d}",
                                 manifest)


def test_resharded_restore_two_writer_shards_single_reader(tmp_path):
    """A checkpoint 'written by 2 localities' restores in one process:
    shard->locality binding is a write-time detail, not a read
    requirement."""
    t = _tree(5)
    _write_two_shard_checkpoint(tmp_path, t)
    cm = CheckpointManager(tmp_path, async_save=False)
    step, back = cm.restore(t)
    assert step == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_corrupt_shard_error_names_the_bad_shard(tmp_path):
    t = _tree(6)
    path = _write_two_shard_checkpoint(tmp_path, t)
    f = path / "shard_00001.bin"
    raw = bytearray(f.read_bytes())
    raw[-1] ^= 0xFF
    f.write_bytes(bytes(raw))
    cm = CheckpointManager(tmp_path, async_save=False)
    with pytest.raises(CheckpointCorruptError, match="shard_00001.bin"):
        cm.restore(t)
    # the untouched shard still reads clean on its own
    m = ckfmt.load_manifest(path)
    good = ckfmt.read_shard(str(path), m["shards"][0])
    assert set(good) == set(range(len(m["shards"][0]["leaves"])))


def test_missing_shard_file_is_corruption(tmp_path):
    t = _tree(7)
    path = _write_two_shard_checkpoint(tmp_path, t)
    (path / "shard_00000.bin").unlink()
    cm = CheckpointManager(tmp_path, async_save=False)
    with pytest.raises(CheckpointCorruptError, match="shard_00000.bin"):
        cm.restore(t)


def test_aborted_tmp_files_never_leak_into_commit(tmp_path):
    """An aborted earlier attempt of the same step left files in the
    temp dir; the next save must start from a clean slate, not commit
    the orphans."""
    cm = CheckpointManager(tmp_path, async_save=False)
    stale = tmp_path / ".tmp_step_00000009"
    stale.mkdir()
    (stale / "shard_00042.bin").write_bytes(b"garbage from a dead run")
    path = cm.save(9, _tree(8))
    assert sorted(p.name for p in path.iterdir()) == [
        "manifest.json", "shard_00000.bin"]


def test_dead_writer_wip_file_pruned_at_commit(tmp_path):
    """A writer killed mid-save_shard leaves shard_N.bin.wip-<pid>; the
    commit (which only runs after the re-spawned write resolved) must
    prune it, never ship it inside the committed checkpoint."""
    t = _tree(9)
    leaves, treedef = jax.tree.flatten(t)
    host = [np.asarray(x) for x in leaves]
    tmp = tmp_path / ".tmp_step_00000004"
    entry = ckfmt.save_shard(str(tmp), 0, range(len(host)), host)
    (tmp / "shard_00000.bin.wip-99999").write_bytes(b"dead writer")
    final = ckfmt.commit_manifest(
        tmp, tmp_path / "step_00000004",
        ckfmt.build_manifest(step=4, treedef=str(treedef),
                             n_leaves=len(host), shards=[entry]))
    assert sorted(p.name for p in final.iterdir()) == [
        "manifest.json", "shard_00000.bin"]


# -- SPMD format path (single-process units; the 2-process drills live
# -- in tests/test_spmd.py) ---------------------------------------------------

def test_spmd_collect_segments_single_process_is_whole_leaf():
    """With one addressable process the persistence view is fully
    addressable: every leaf yields exactly one whole-leaf (unsliced)
    segment - the SPMD path degenerates to the classic layout."""
    from repro.checkpoint import spmd as ckspmd

    t = _tree(20)
    indices, slices, arrays = ckspmd.collect_segments(t)
    assert indices == [0, 1, 2]
    assert slices == [None, None, None]
    for a, b in zip(arrays, [np.asarray(x) for x in jax.tree.leaves(t)]):
        np.testing.assert_array_equal(a, b)


def test_spmd_write_shard_roundtrips_through_restore(tmp_path):
    """write_spmd_shard -> driver-style manifest commit -> plain
    CheckpointManager.restore: the SPMD writer and the classic reader
    agree on the bytes."""
    from repro.checkpoint import spmd as ckspmd

    t = _tree(21)
    leaves, treedef = jax.tree.flatten(t)
    tmp = tmp_path / ".tmp_step_00000005"
    entry = ckspmd.write_spmd_shard(str(tmp), 0, t)
    ckfmt.commit_manifest(
        tmp, tmp_path / "step_00000005",
        ckfmt.build_manifest(step=5, treedef=str(treedef),
                             n_leaves=len(leaves), shards=[entry]))
    cm = CheckpointManager(tmp_path, async_save=False)
    step, back = cm.restore(t)
    assert step == 5
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_sliced_two_host_checkpoint_restores_via_manager(tmp_path):
    """A checkpoint laid out the way two SPMD hosts write it - each leaf
    split row-wise across two shard files as sliced segments - restores
    through the ordinary CheckpointManager path (N=2 hosts -> M=1)."""
    t = {"w": np.arange(32, dtype=np.float32).reshape(8, 4),
         "b": np.arange(6, dtype=np.int32)}
    leaves, treedef = jax.tree.flatten(t)
    tmp = tmp_path / ".tmp_step_00000009"
    entries = []
    for host in (0, 1):                      # each host: its half rows
        idx, sls, arrs = [], [], []
        for i, leaf in enumerate(leaves):
            n = leaf.shape[0] // 2
            lo, hi = host * n, (host + 1) * n
            idx.append(i)
            sls.append(([(lo, hi)] + [(0, d) for d in leaf.shape[1:]],
                        list(leaf.shape)))
            arrs.append(leaf[lo:hi])
        entries.append(ckfmt.save_shard(str(tmp), host, idx, arrs,
                                        slices=sls))
    ckfmt.commit_manifest(
        tmp, tmp_path / "step_00000009",
        ckfmt.build_manifest(step=9, treedef=str(treedef),
                             n_leaves=len(leaves), shards=entries))
    cm = CheckpointManager(tmp_path, async_save=False)
    step, back = cm.restore(t)
    assert step == 9
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# -- fault injection: every corruption names its culprit, never a torn
# -- restore ------------------------------------------------------------------

def test_truncated_shard_file_mid_leaf_names_shard_and_leaf(tmp_path):
    """A shard file cut off mid-leaf (disk full / writer died post-
    rename corruption) must raise naming the shard and the leaf it
    tore, not hand back a short array."""
    cm = CheckpointManager(tmp_path, async_save=False)
    t = _tree(10)
    path = cm.save(3, t)
    f = next(path.glob("shard_*.bin"))
    m = json.loads((path / "manifest.json").read_text())
    last = m["shards"][0]["leaves"][-1]
    import os
    os.truncate(f, last["offset"] + last["nbytes"] // 2)  # cut last leaf
    with pytest.raises(CheckpointCorruptError,
                       match=rf"truncated at leaf {last['index']}"):
        cm.restore(t)


def test_corrupted_manifest_json_is_corruption_not_a_crash(tmp_path):
    cm = CheckpointManager(tmp_path, async_save=False)
    path = cm.save(2, _tree(11))
    (path / "manifest.json").write_text('{"format": "phyrax-ckpt/3", ')
    with pytest.raises(CheckpointCorruptError, match="does not parse"):
        cm.restore(_tree(11))


def test_unknown_format_version_refused(tmp_path):
    cm = CheckpointManager(tmp_path, async_save=False)
    path = cm.save(2, _tree(12))
    m = json.loads((path / "manifest.json").read_text())
    m["format"] = "phyrax-ckpt/99"
    (path / "manifest.json").write_text(json.dumps(m))
    with pytest.raises(CheckpointCorruptError, match="phyrax-ckpt/99"):
        cm.restore(_tree(12))


def test_unreferenced_stale_shard_pruned_at_commit(tmp_path):
    """A stale shard from an aborted attempt with a DIFFERENT world size
    (so the name collides with nothing this save writes) must not be
    committed: commit prunes everything the manifest does not
    reference."""
    t = _tree(13)
    leaves, treedef = jax.tree.flatten(t)
    host = [np.asarray(x) for x in leaves]
    tmp = tmp_path / ".tmp_step_00000006"
    entry = ckfmt.save_shard(str(tmp), 0, range(len(host)), host)
    (tmp / "shard_00007.bin").write_bytes(b"stale shard, bigger world")
    (tmp / "shard_00000.bin.wip-12345").write_bytes(b"dead writer")
    final = ckfmt.commit_manifest(
        tmp, tmp_path / "step_00000006",
        ckfmt.build_manifest(step=6, treedef=str(treedef),
                             n_leaves=len(host), shards=[entry]))
    assert sorted(p.name for p in final.iterdir()) == [
        "manifest.json", "shard_00000.bin"]


def test_missing_device_shard_segment_names_the_leaf(tmp_path):
    """An SPMD checkpoint whose manifest references a leaf whose
    segments do not cover it (a host's shard file lost after commit,
    manifest hand-edited, ...) must fail the assembly naming the leaf."""
    leaf = np.arange(24, dtype=np.float32).reshape(6, 4)
    e0 = ckfmt.save_shard(str(tmp_path), 0, [0], [leaf[:3]],
                          slices=[([(0, 3), (0, 4)], [6, 4])])
    segs = ckfmt.read_shard_segments(str(tmp_path), e0)
    with pytest.raises(CheckpointCorruptError,
                       match="leaf 0.*segments cover 12 of 24"):
        ckfmt.assemble_leaf(0, segs)


def test_overlapping_segments_are_corruption_not_garbage():
    """Overlapping device-shard segments could satisfy a naive element
    COUNT while leaving part of the leaf uninitialized; they must be
    rejected, never silently assembled."""
    leaf = np.arange(4, dtype=np.float32)
    seg = {"index": 0, "slice": [[0, 2]], "global_shape": [4],
           "array": leaf[:2]}
    with pytest.raises(CheckpointCorruptError, match="overlap"):
        ckfmt.assemble_leaf(0, [seg, dict(seg)])


def test_whole_leaf_duplicated_across_shards_is_corruption():
    seg = {"index": 0, "slice": None, "global_shape": None,
           "array": np.ones(3)}
    with pytest.raises(CheckpointCorruptError, match="duplicated"):
        ckfmt.assemble_leaf(0, [seg, dict(seg)])


def test_failed_save_commits_nothing(tmp_path):
    """Atomic failure: a save whose dependency poisons never commits a
    manifest - the step directory must not exist, latest stays None."""
    g = FuturizedGraph(max_workers=2, name="ckpt-atomic")
    try:
        cm = CheckpointManager(tmp_path, graph=g)
        poison = g.defer(_boom, name="retire")
        fut = cm.save(5, _tree(), deps=(poison,))
        with pytest.raises(RuntimeError, match="boom"):
            fut.result(timeout=30)
        assert not (tmp_path / "step_00000005").exists()
        assert cm.latest_step() is None
    finally:
        g.shutdown(wait=True)


def test_restart_resumes_training(tmp_path):
    """Full drill: train, 'crash', resume; trajectories must continue."""
    from repro.launch import train as train_mod

    args = train_mod.parser().parse_args([
        "--arch", "qwen2.5-3b", "--steps", "8", "--batch", "4",
        "--seq", "16", "--ckpt", str(tmp_path), "--ckpt-every", "4",
        "--log-every", "4", "--fail-at-step", "6"])
    with pytest.raises(RuntimeError, match="injected node failure"):
        train_mod.run(args)
    # resume completes and produces finite loss continuing from step 4
    args2 = train_mod.parser().parse_args([
        "--arch", "qwen2.5-3b", "--steps", "8", "--batch", "4",
        "--seq", "16", "--ckpt", str(tmp_path), "--ckpt-every", "4",
        "--log-every", "4", "--resume"])
    out = train_mod.run(args2)
    assert np.isfinite(out["final_loss"])

    # and the resumed run must equal an uninterrupted run bit-for-bit
    args3 = train_mod.parser().parse_args([
        "--arch", "qwen2.5-3b", "--steps", "8", "--batch", "4",
        "--seq", "16", "--log-every", "4"])
    ref = train_mod.run(args3)
    assert abs(ref["final_loss"] - out["final_loss"]) < 1e-4
