"""Checkpointing: roundtrip, checksums, atomicity, GC, async, restart."""
import json
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import CheckpointManager


def _tree(key=0):
    k = jax.random.PRNGKey(key)
    return {"w": jax.random.normal(k, (8, 16)),
            "nested": {"b": jnp.arange(5, dtype=jnp.int32),
                       "s": jnp.float32(3.5)}}


def test_roundtrip(tmp_path):
    cm = CheckpointManager(tmp_path, async_save=False)
    t = _tree()
    cm.save(10, t, meta={"note": "hi"})
    step, back = cm.restore(t)
    assert step == 10
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert cm.meta["note"] == "hi"


def test_async_save_then_restore(tmp_path):
    cm = CheckpointManager(tmp_path, async_save=True)
    t = _tree(1)
    fut = cm.save(3, t)
    cm.wait()
    step, back = cm.restore(t)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(back["w"]), np.asarray(t["w"]))


def test_checksum_corruption_detected(tmp_path):
    cm = CheckpointManager(tmp_path, async_save=False)
    t = _tree(2)
    path = cm.save(1, t)
    # flip a byte in the first array file
    f = next(path.glob("arr_*.npy"))
    raw = bytearray(f.read_bytes())
    raw[-1] ^= 0xFF
    f.write_bytes(bytes(raw))
    with pytest.raises(IOError, match="checksum"):
        cm.restore(t)
    # non-strict mode loads anyway
    step, _ = cm.restore(t, strict_checksums=False)
    assert step == 1


def test_gc_keeps_last_k(tmp_path):
    cm = CheckpointManager(tmp_path, keep=2, async_save=False)
    t = _tree(3)
    for s in (1, 2, 3, 4):
        cm.save(s, t)
    assert cm.all_steps() == [3, 4]


def test_leaf_count_mismatch_raises(tmp_path):
    cm = CheckpointManager(tmp_path, async_save=False)
    cm.save(1, _tree(4))
    with pytest.raises(ValueError, match="leaves"):
        cm.restore({"only": jnp.zeros(3)})


def test_restart_resumes_training(tmp_path):
    """Full drill: train, 'crash', resume; trajectories must continue."""
    import argparse
    from repro.launch import train as train_mod

    args = train_mod.parser().parse_args([
        "--arch", "qwen2.5-3b", "--steps", "8", "--batch", "4",
        "--seq", "16", "--ckpt", str(tmp_path), "--ckpt-every", "4",
        "--log-every", "4", "--fail-at-step", "6"])
    with pytest.raises(RuntimeError, match="injected node failure"):
        train_mod.run(args)
    # resume completes and produces finite loss continuing from step 4
    args2 = train_mod.parser().parse_args([
        "--arch", "qwen2.5-3b", "--steps", "8", "--batch", "4",
        "--seq", "16", "--ckpt", str(tmp_path), "--ckpt-every", "4",
        "--log-every", "4", "--resume"])
    out = train_mod.run(args2)
    assert np.isfinite(out["final_loss"])

    # and the resumed run must equal an uninterrupted run bit-for-bit
    args3 = train_mod.parser().parse_args([
        "--arch", "qwen2.5-3b", "--steps", "8", "--batch", "4",
        "--seq", "16", "--log-every", "4"])
    ref = train_mod.run(args3)
    assert abs(ref["final_loss"] - out["final_loss"]) < 1e-4
