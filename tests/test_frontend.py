"""Frontend: @futurize tracing, Plan/Session, shared CLI flags, and parity
with the launcher shims (resume-from-checkpoint drill)."""
import time

import numpy as np
import pytest

from repro.core import steps as steps_lib
from repro.core.futures import FuturizedGraph, Lane, PhyFuture
from repro.frontend import (Plan, cli_args, futurize, plan_from_args,
                            tracing)

ARCH = "qwen2.5-3b"


def _plan(**kw):
    kw.setdefault("arch", ARCH)
    kw.setdefault("batch", 4)
    kw.setdefault("seq", 16)
    return Plan(**kw)


# -- @futurize tracing -------------------------------------------------------

def test_untraced_futurized_call_runs_inline():
    @futurize
    def f(x):
        return x + 1
    assert f(1) == 2                      # plain value, no graph involved


def test_traced_calls_become_graph_nodes_with_edges():
    @futurize
    def load(i):
        return i * 10

    @futurize
    def use(x):
        return x + 1

    with tracing() as tr:
        a = load(3)
        b = use(a)
        assert isinstance(a, PhyFuture) and isinstance(b, PhyFuture)
        assert b.result() == 31
    sig = tr.signature()
    assert sig[0] == ("load:0", "COMPUTE", ())
    assert sig[1] == ("use:0", "COMPUTE", (0,))   # edge found from the arg


def test_traced_tree_shape_is_deterministic_across_runs():
    def program():
        @futurize
        def load(i):
            return i

        @futurize
        def mul(x, y):
            return x * y

        with tracing() as tr:
            xs = [load(i) for i in range(4)]
            ys = [mul(xs[i], xs[(i + 1) % 4]) for i in range(4)]
            assert tr.graph.when_all(ys).result() == [0, 2, 6, 0]
        return tr.signature()

    assert program() == program()


def test_futurize_composes_with_when_all_and_tree_join():
    @futurize
    def val(i):
        return i

    with tracing() as tr:
        g = tr.graph
        futs = [val(i) for i in range(5)]
        assert g.when_all(futs).result() == [0, 1, 2, 3, 4]
        tree = {"a": futs[2], "b": [futs[4], 7]}
        assert g.tree_join(tree).result() == {"a": 2, "b": [4, 7]}


def test_nested_futurized_calls_run_inline_on_workers():
    @futurize
    def inner(x):
        return x * 2

    @futurize
    def outer(x):
        return inner(x) + 1     # runs on a worker thread: inline fallback

    with tracing() as tr:
        assert outer(5).result() == 11
    assert [n.name for n in tr.nodes] == ["outer:0"]


def test_futurize_lane_and_untrace_on_exit():
    @futurize(lane=Lane.PREFETCH, name="fetch")
    def f():
        return 1

    with tracing() as tr:
        fut = f()
        assert fut.lane is Lane.PREFETCH
        fut.result()
    assert f() == 1                       # context exited: inline again
    assert tr.nodes[0].name == "fetch:0"


# -- runtime stats histograms ------------------------------------------------

def test_runtime_stats_histograms_bucketed_by_lane():
    g = FuturizedGraph(max_workers=2, name="hist")
    try:
        for _ in range(4):
            g.defer(time.sleep, 0.002, lane=Lane.PREFETCH).result()
        g.defer(lambda: None, lane=Lane.CHECKPOINT).result()
    finally:
        g.shutdown(wait=True)
    js = g.stats().to_json()
    hist = js["lane_time_hist"]
    assert hist["edges_s"] == [1e-4, 1e-3, 1e-2, 1e-1, 1.0]
    assert sum(hist["counts"]["PREFETCH"]) == 4
    assert sum(hist["counts"]["CHECKPOINT"]) == 1
    # histogram totals agree with the per-lane completion counters
    for lane, counts in hist["counts"].items():
        assert sum(counts) == js["per_lane"][lane]
    assert g.stats().hist_lines()         # non-empty human-readable form


# -- Plan / Session ----------------------------------------------------------

def test_steps_builders_accept_plan_keyword():
    plan = _plan()
    step = steps_lib.make_train_step(plan=plan)
    assert isinstance(step, steps_lib.TrainStep)
    assert step.strategy.name == "phylanx"
    # explicit arguments win over the plan
    step2 = steps_lib.make_train_step(
        plan=plan, strategy=steps_lib.Strategy(name="horovod"))
    assert step2.strategy.name == "horovod"


def test_cli_args_shared_flags_and_plan_from_args():
    ap = cli_args(seq=64, batch=8)
    args = ap.parse_args(["--arch", ARCH, "--full", "--batch", "2"])
    assert args.tiny is False and args.batch == 2 and args.data == 1
    plan = plan_from_args(args, tiny=True)
    assert plan.arch == ARCH and plan.batch == 2 and plan.tiny is True


def test_session_train_resume_matches_launcher(tmp_path):
    """Session drill: train, 'crash', resume on the same session - and the
    result must equal an uninterrupted launcher-shim run bit-for-bit."""
    from repro.launch import train as train_mod

    hooks_seen = []

    class Hooks:
        def on_log(self, it, loss):
            hooks_seen.append((it, loss))

    with _plan().compile() as session:
        with pytest.raises(RuntimeError, match="injected node failure"):
            session.train(steps=8, ckpt_dir=str(tmp_path), ckpt_every=4,
                          log_every=4, fail_at_step=6, verbose=False)
        out = session.train(steps=8, ckpt_dir=str(tmp_path), ckpt_every=4,
                            log_every=4, resume=True, hooks=Hooks(),
                            verbose=False)
    assert np.isfinite(out["final_loss"])
    assert hooks_seen and hooks_seen[-1][0] == 7

    args = train_mod.parser().parse_args(
        ["--arch", ARCH, "--steps", "8", "--batch", "4", "--seq", "16",
         "--log-every", "4"])
    ref = train_mod.run(args)
    assert abs(ref["final_loss"] - out["final_loss"]) < 1e-4


def test_session_serve_decode_steps_are_named_graph_nodes():
    with _plan().compile() as session:
        out = session.serve(requests=4, slots=2, prompt_len=16, gen_len=4,
                            verbose=False)
    assert out["tokens_per_s"] > 0
    decode = [n for n in out["nodes"] if n.startswith("decode:")]
    assert decode == [f"decode:w{w}:t{t}" for w in range(2)
                      for t in range(4)]
    # decode rides the step-critical COMPUTE lane; wave prep is PREFETCH
    trace = {name: lane for name, lane, _ in out["trace"]}
    assert trace["decode:w0:t0"] == "COMPUTE"
    assert trace["wave:0"] == "PREFETCH"
    # each decode node's edge is the previous node in its wave's chain
    by_name = {name: deps for name, _, deps in out["trace"]}
    idx = {name: i for i, (name, _, _) in enumerate(out["trace"])}
    assert by_name["decode:w0:t1"] == (idx["decode:w0:t0"],)


def test_session_serve_zero_requests_serves_nothing():
    with _plan().compile() as session:
        out = session.serve(requests=0, slots=2, prompt_len=16, gen_len=4,
                            verbose=False)
    assert out["requests"] == 0 and out["tokens"] == 0
    assert out["tokens_per_s"] == 0.0 and out["nodes"] == []
