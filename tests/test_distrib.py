"""Multi-locality runtime: active messages, AGAS, cross-process spawn,
error/cancellation across the wire, locality loss, Session parity, and
locality-owned checkpoint shards (save on owners, killed-owner save,
N->M resharded restore).

Most tests drive 2-3 REAL processes (``multiprocessing.spawn``) through a
module-scoped ``DistributedGraph``; everything a worker runs must be a
module-level function here, because it crosses the wire by reference.
"""
import shutil
import time
from concurrent.futures import CancelledError
from pathlib import Path

import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.checkpoint.format import CheckpointCorruptError, load_manifest
from repro.core.futures import FuturizedGraph, Lane
from repro.data.pipeline import Prefetcher
from repro.distrib import (DistributedGraph, ObjectDirectory, RemoteRef)
from repro.distrib.messaging import Endpoint
from repro.frontend import Plan

ARCH = "qwen2.5-3b"


# -- module-level task functions (ship by reference) -------------------------

def build(i):
    return {"x": np.full((4,), i)}


def double(b):
    return {k: v * 2 for k, v in b.items()}


def boom(i):
    raise ValueError(f"poisoned batch {i}")


def slow_mul(i, delay=0.4):
    time.sleep(delay)
    return i * 10


class FlakyStream:
    """Picklable stream whose ``batch_at`` raises for one step."""

    def __init__(self, poison_step):
        self.poison_step = poison_step

    def batch_at(self, step):
        if step == self.poison_step:
            raise ValueError(f"poisoned batch {step}")
        return {"tokens": np.full((2, 4), step, np.int32)}


# -- fixtures ----------------------------------------------------------------

@pytest.fixture(scope="module")
def cluster():
    """One driver + two worker localities, reused across tests."""
    dg = DistributedGraph(localities=3, name="test-cluster")
    yield dg
    dg.shutdown()


# -- messaging (in-process endpoints) ----------------------------------------

def test_request_ack_post_and_handler_errors():
    a, b = Endpoint(0), Endpoint(1)
    seen = []
    b.register("echo", lambda src, p: {"from": src, "got": p})
    b.register("note", lambda src, p: seen.append(p))
    b.register("fail", lambda src, p: 1 / 0)
    try:
        a.connect(1, b.address)
        out = a.request(1, "echo", {"arr": np.arange(5)})
        assert out["from"] == 0 and (out["got"]["arr"] == np.arange(5)).all()
        a.post(1, "note", "fire-and-forget")
        deadline = time.time() + 5
        while not seen and time.time() < deadline:
            time.sleep(0.01)
        assert seen == ["fire-and-forget"]
        with pytest.raises(ZeroDivisionError):   # remote exc re-raises here
            a.request(1, "fail")
        assert a.bytes_sent > 0 and b.bytes_recv > 0
    finally:
        a.close()
        b.close()


def test_agas_directory_local_put_fetch_free():
    d = ObjectDirectory(rank=0)
    ref = d.put({"w": np.ones((3,))}, summary="weights")
    assert isinstance(ref, RemoteRef) and ref.owner == 0 and ref.nbytes == 24
    assert (d.fetch(ref)["w"] == 1).all()
    d.free(ref)
    with pytest.raises(KeyError):
        d.fetch(ref)


# -- promise nodes (the cross-wire resolution primitive) ---------------------

def test_promise_resolves_dependents_and_rejects_double_set():
    g = FuturizedGraph(max_workers=2, name="promise")
    try:
        p = g.promise(name="remote-result")
        dep = g.defer(lambda x: x + 1, p)
        assert not p.done()
        assert p.set_result(41) is True
        assert dep.result() == 42
        assert p.set_result(0) is False         # late result: discarded
        q = g.promise(name="remote-error")
        dq = g.defer(lambda x: x, q)
        assert q.set_exception(ValueError("wire")) is True
        with pytest.raises(ValueError, match="wire"):
            dq.result()
        with pytest.raises(RuntimeError, match="not a promise"):
            dep.set_result(1)                   # scheduler-owned node
    finally:
        g.shutdown(wait=True)


# -- distributed graph over real processes -----------------------------------

def test_remote_spawn_chain_and_data_affinity(cluster):
    a = cluster.defer(build, 3, lane=Lane.PREFETCH, name="build")
    b = cluster.defer(double, a, name="double")
    assert (b.result()["x"] == 6).all()
    # the dependent followed its input's locality (data affinity)
    assert a.home in (1, 2) and b.home == a.home


def test_pin_keeps_result_remote_and_cross_locality_fetch(cluster):
    pinned = cluster.defer(build, 7, locality=1, pin=True, name="pinned")
    ref = pinned.result()
    assert isinstance(ref, RemoteRef) and ref.owner == 1
    assert (cluster.fetch(ref)["x"] == 7).all()          # driver <- worker1
    far = cluster.defer(double, ref, locality=2, name="far")
    assert (far.result()["x"] == 14).all()               # worker2 <- worker1


def test_remote_error_poisons_only_dependents_and_locality_survives(cluster):
    bad = cluster.defer(boom, 9, locality=1, name="bad")
    dep = cluster._graph.defer(lambda x: x, bad, name="dep")
    sibling = cluster.defer(build, 1, locality=1, name="sibling")
    with pytest.raises(ValueError, match="poisoned batch 9"):
        dep.result(timeout=30)
    assert (sibling.result(timeout=30)["x"] == 1).all()
    after = cluster.defer(build, 2, locality=1, name="after")
    assert (after.result(timeout=30)["x"] == 2).all()    # locality alive


def test_upstream_poison_settles_undispatched_remote_task(cluster):
    """A distributed task whose dependency fails BEFORE dispatch must
    still settle (with the original error) - a stranded promise would
    hang barrier/shutdown forever."""
    bad = cluster.defer(boom, 4, locality=1, name="upstream")
    downstream = cluster.defer(double, bad, name="downstream")
    with pytest.raises(ValueError, match="poisoned batch 4"):
        downstream.result(timeout=30)
    cluster.barrier(timeout=30)          # nothing left outstanding
    assert cluster.stats()["outstanding"] == 0


def test_cancel_before_dispatch_releases_record(cluster):
    gate = cluster.defer(slow_mul, 1, locality=1, name="gate")
    dep = cluster.defer(double, gate, name="dep-gated")
    cluster.cancel(dep)                  # before its dispatch node ran
    with pytest.raises(CancelledError):
        dep.result(timeout=30)
    assert gate.result(timeout=30) == 10
    cluster.barrier(timeout=30)
    assert cluster.stats()["outstanding"] == 0


def test_cancellation_across_the_wire(cluster):
    # worker graphs have 2 threads: occupy both, then cancel the queued one
    s1 = cluster.defer(slow_mul, 1, locality=1, name="slow1")
    s2 = cluster.defer(slow_mul, 2, locality=1, name="slow2")
    s3 = cluster.defer(slow_mul, 3, locality=1, name="slow3")
    time.sleep(0.1)
    cluster.cancel(s3)
    with pytest.raises(CancelledError):
        s3.result(timeout=30)
    assert s1.result(timeout=30) == 10 and s2.result(timeout=30) == 20


def test_prefetcher_remote_poison_kills_only_that_batch(cluster):
    pf = Prefetcher(FlakyStream(poison_step=1), shardings=None, depth=2,
                    graph=cluster._graph, dgraph=cluster)
    try:
        assert (pf.get(0)["tokens"] == 0).all()
        with pytest.raises(ValueError, match="poisoned batch 1"):
            pf.get(1)
        assert (pf.get(2)["tokens"] == 2).all()          # stream continues
    finally:
        pf.close()


def test_pin_honored_on_driver_placement(cluster):
    """pin=True must yield a RemoteRef regardless of where placement
    lands - including the driver-local fast path."""
    fut = cluster.defer(build, 8, locality=0, pin=True, name="pin-local")
    ref = fut.result(timeout=30)
    assert isinstance(ref, RemoteRef) and ref.owner == 0
    assert (cluster.fetch(ref)["x"] == 8).all()


def test_foreign_graph_dependency_raises_and_leaves_nothing_behind(cluster):
    other = FuturizedGraph(max_workers=1, name="other")
    try:
        foreign = other.defer(lambda: 1)
        with pytest.raises(ValueError, match="different graph"):
            cluster.defer(double, foreign, locality=1, name="foreign")
        cluster.barrier(timeout=30)      # no stranded promise/record
        assert cluster.stats()["outstanding"] == 0
    finally:
        other.shutdown(wait=True)


def test_replicate_checksum_vote_across_localities(cluster):
    fut = cluster.replicate(build, 5, n=2, name="rep")
    assert (fut.result(timeout=30)["x"] == 5).all()


def test_unpicklable_function_fails_cleanly(cluster):
    fut = cluster.defer(lambda: 1, locality=1, name="closure")
    with pytest.raises(RuntimeError, match="not picklable"):
        fut.result(timeout=30)


def test_remote_stats_visible_from_driver(cluster):
    cluster.defer(build, 1, locality=1, name="warm").result(timeout=30)
    st = cluster.remote_stats(1)
    assert st["completed"] >= 1
    assert st["lane_time_hist"]["labels"][0] == "<100us"


def test_worker_loss_respawns_in_flight_tasks():
    dg = DistributedGraph(localities=3, name="kill-drill")
    try:
        futs = [dg.defer(slow_mul, i, locality=2, name=f"r{i}")
                for i in range(3)]
        time.sleep(0.1)                  # let the first task start
        dg.group.kill(2)
        assert [f.result(timeout=60) for f in futs] == [0, 10, 20]
        st = dg.stats()
        assert st["respawned"] >= 1 and st["alive_workers"] == [1]
    finally:
        dg.shutdown()


# -- locality-owned checkpoint shards -----------------------------------------

def _ckpt_tree(k=0):
    rng = np.random.default_rng(k)
    return {"w": rng.normal(size=(6, 4)).astype(np.float32),
            "b": np.arange(5, dtype=np.int32),
            "nested": {"s": np.float32(1.5),
                       "t": np.arange(3.0, dtype=np.float64)}}


def _assert_tree_equal(t, back):
    import jax

    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_distributed_save_shards_written_by_owners(cluster, tmp_path):
    """Each locality writes its own shard: the ownership map must cover
    the driver AND both workers (writer rank is recorded from
    PHYRAX_LOCALITY_RANK inside the executing process, so this proves
    the writes really ran there)."""
    cm = CheckpointManager(tmp_path, graph=cluster.graph, dgraph=cluster)
    t = _ckpt_tree(1)
    cm.save(4, t, meta={"who": "owners"})
    cm.wait()
    m = load_manifest(tmp_path / "step_00000004")
    assert set(m["ownership"]) == {"0", "1", "2"}    # 4 leaves, 3 ranks
    assert m["n_shards"] == 3 and m["n_leaves"] == 4
    # restore spreads shard reads over the same localities
    step, back = cm.restore(t)
    assert step == 4
    _assert_tree_equal(t, back)
    assert cm.meta["who"] == "owners"


def test_host_copy_save_accounts_ckpt_leaf_wire_bytes(cluster, tmp_path):
    """Host-copy mode ships each worker-owned shard its leaf bytes in
    the spawn payload; the ``ckpt_leaf_wire_bytes`` counter must record
    exactly those bytes (the SPMD drill asserts the same counter stays
    0 - see tests/test_spmd.py)."""
    import jax

    from repro.checkpoint.format import assign_shards

    cm = CheckpointManager(tmp_path, graph=cluster.graph, dgraph=cluster)
    before = cluster.stats()["ckpt_leaf_wire_bytes"]
    t = _ckpt_tree(9)
    host = [np.asarray(x) for x in jax.tree.leaves(t)]
    expected = sum(host[i].nbytes
                   for _sid, rank, idx in assign_shards(len(host), [0, 1, 2])
                   for i in idx if rank != 0)
    assert expected > 0
    cm.save(2, t)
    cm.wait()
    after = cluster.stats()["ckpt_leaf_wire_bytes"]
    assert after - before == expected


def test_corrupt_shard_error_crosses_the_wire(cluster, tmp_path):
    """CheckpointCorruptError raised inside a worker's read_shard task
    re-raises at the driver and names the bad shard."""
    cm = CheckpointManager(tmp_path, graph=cluster.graph, dgraph=cluster)
    t = _ckpt_tree(2)
    cm.save(1, t)
    cm.wait()
    f = tmp_path / "step_00000001" / "shard_00001.bin"
    raw = bytearray(f.read_bytes())
    raw[-1] ^= 0xFF
    f.write_bytes(bytes(raw))
    with pytest.raises(CheckpointCorruptError, match="shard_00001.bin"):
        cm.restore(t)
    step, _ = cm.restore(t, strict_checksums=False)
    assert step == 1


def test_save_completes_when_owner_locality_killed(tmp_path):
    """The failure drill: a shard's owning locality is SIGKILLed before
    its write dispatches; the idempotent task re-targets the driver and
    the manifest still commits - never a torn checkpoint."""
    g = FuturizedGraph(max_workers=2, name="ckpt-kill")
    dg = DistributedGraph(localities=2, graph=g, name="ckpt-kill")
    try:
        cm = CheckpointManager(tmp_path, graph=g, dgraph=dg)
        hold = g.promise(name="hold")
        t = _ckpt_tree(3)
        cm.save(3, t, deps=(hold,))      # shard 1 owned by worker 1
        dg.group.kill(1)
        hold.set_result(None)
        cm.wait()
        m = load_manifest(tmp_path / "step_00000003")
        assert m["n_shards"] == 2
        assert set(m["ownership"]) == {"0"}    # fallback writer: driver
        step, back = cm.restore(t)
        assert step == 3
        _assert_tree_equal(t, back)
    finally:
        dg.shutdown()
        g.shutdown(wait=True)


# -- Session parity -----------------------------------------------------------

def _plan(**kw):
    kw.setdefault("arch", ARCH)
    kw.setdefault("batch", 4)
    kw.setdefault("seq", 16)
    return Plan(**kw)


def test_session_serve_parity_single_vs_multi_locality():
    kw = dict(requests=4, slots=2, prompt_len=16, gen_len=4, verbose=False)
    with _plan().compile() as single:
        ref = single.serve(**kw)
    with _plan(localities=2).compile() as multi:
        out = multi.serve(**kw)
        dstats = out["runtime_stats"]["distributed"]
    assert out["tokens"] == ref["tokens"] and out["requests"] == 4
    decode = [n for n in out["nodes"] if n.startswith("decode:")]
    assert decode == [n for n in ref["nodes"] if n.startswith("decode:")]
    assert dstats["dispatched"].get(1, 0) > 0    # waves really went remote


def test_session_train_two_localities_matches_single_even_killed():
    """The acceptance drill: a 2-locality run (with a worker SIGKILLed
    mid-run!) produces the same loss as the single-process run - remote
    prefetch changes where batches are built, never what they contain."""
    with _plan().compile() as single:
        ref = single.train(steps=6, log_every=3, verbose=False)
    with _plan(localities=2).compile() as multi:
        out = multi.train(steps=6, log_every=3, kill_locality_at_step=3,
                          verbose=False)
        dstats = out["runtime_stats"]["distributed"]
    assert abs(out["final_loss"] - ref["final_loss"]) < 1e-4
    assert dstats["dispatched"].get(1, 0) > 0
    assert dstats["alive_workers"] == []         # the drill really killed it


def test_train_resharded_restore_2_to_1_and_2_to_3(tmp_path):
    """The acceptance round-trip: a 2-locality run writes locality-owned
    shards; restoring into 1 AND into 3 localities continues training
    with bit-identical loss to an uninterrupted single-process run."""
    steps, kw = 6, dict(log_every=3, verbose=False)
    with _plan().compile() as ref_s:
        ref = ref_s.train(steps=steps, **kw)

    ck = str(tmp_path / "ck")
    with _plan(localities=2, ckpt_dir=ck).compile() as writer:
        writer.train(steps=4, ckpt_every=4, **kw)
    m = load_manifest(Path(ck) / "step_00000004")
    assert set(m["ownership"]) == {"0", "1"}     # both localities wrote

    ck2 = str(tmp_path / "ck2")                  # second copy: each resume
    shutil.copytree(ck, ck2)                     # writes new checkpoints

    with _plan().compile() as single:            # N=2 -> M=1
        out1 = single.train(steps=steps, ckpt_dir=ck, resume=True, **kw)
    with _plan(localities=3).compile() as multi:  # N=2 -> M=3
        out3 = multi.train(steps=steps, ckpt_dir=ck2, resume=True, **kw)

    assert out1["final_loss"] == pytest.approx(ref["final_loss"], abs=1e-6)
    assert out3["final_loss"] == pytest.approx(ref["final_loss"], abs=1e-6)
