"""Multi-locality runtime: active messages, AGAS, cross-process spawn,
error/cancellation across the wire, locality loss, and Session parity.

Most tests drive 2-3 REAL processes (``multiprocessing.spawn``) through a
module-scoped ``DistributedGraph``; everything a worker runs must be a
module-level function here, because it crosses the wire by reference.
"""
import time
from concurrent.futures import CancelledError

import numpy as np
import pytest

from repro.core.futures import FuturizedGraph, Lane
from repro.data.pipeline import Prefetcher
from repro.distrib import (DistributedGraph, ObjectDirectory, RemoteRef)
from repro.distrib.messaging import Endpoint
from repro.frontend import Plan

ARCH = "qwen2.5-3b"


# -- module-level task functions (ship by reference) -------------------------

def build(i):
    return {"x": np.full((4,), i)}


def double(b):
    return {k: v * 2 for k, v in b.items()}


def boom(i):
    raise ValueError(f"poisoned batch {i}")


def slow_mul(i, delay=0.4):
    time.sleep(delay)
    return i * 10


class FlakyStream:
    """Picklable stream whose ``batch_at`` raises for one step."""

    def __init__(self, poison_step):
        self.poison_step = poison_step

    def batch_at(self, step):
        if step == self.poison_step:
            raise ValueError(f"poisoned batch {step}")
        return {"tokens": np.full((2, 4), step, np.int32)}


# -- fixtures ----------------------------------------------------------------

@pytest.fixture(scope="module")
def cluster():
    """One driver + two worker localities, reused across tests."""
    dg = DistributedGraph(localities=3, name="test-cluster")
    yield dg
    dg.shutdown()


# -- messaging (in-process endpoints) ----------------------------------------

def test_request_ack_post_and_handler_errors():
    a, b = Endpoint(0), Endpoint(1)
    seen = []
    b.register("echo", lambda src, p: {"from": src, "got": p})
    b.register("note", lambda src, p: seen.append(p))
    b.register("fail", lambda src, p: 1 / 0)
    try:
        a.connect(1, b.address)
        out = a.request(1, "echo", {"arr": np.arange(5)})
        assert out["from"] == 0 and (out["got"]["arr"] == np.arange(5)).all()
        a.post(1, "note", "fire-and-forget")
        deadline = time.time() + 5
        while not seen and time.time() < deadline:
            time.sleep(0.01)
        assert seen == ["fire-and-forget"]
        with pytest.raises(ZeroDivisionError):   # remote exc re-raises here
            a.request(1, "fail")
        assert a.bytes_sent > 0 and b.bytes_recv > 0
    finally:
        a.close()
        b.close()


def test_agas_directory_local_put_fetch_free():
    d = ObjectDirectory(rank=0)
    ref = d.put({"w": np.ones((3,))}, summary="weights")
    assert isinstance(ref, RemoteRef) and ref.owner == 0 and ref.nbytes == 24
    assert (d.fetch(ref)["w"] == 1).all()
    d.free(ref)
    with pytest.raises(KeyError):
        d.fetch(ref)


# -- promise nodes (the cross-wire resolution primitive) ---------------------

def test_promise_resolves_dependents_and_rejects_double_set():
    g = FuturizedGraph(max_workers=2, name="promise")
    try:
        p = g.promise(name="remote-result")
        dep = g.defer(lambda x: x + 1, p)
        assert not p.done()
        assert p.set_result(41) is True
        assert dep.result() == 42
        assert p.set_result(0) is False         # late result: discarded
        q = g.promise(name="remote-error")
        dq = g.defer(lambda x: x, q)
        assert q.set_exception(ValueError("wire")) is True
        with pytest.raises(ValueError, match="wire"):
            dq.result()
        with pytest.raises(RuntimeError, match="not a promise"):
            dep.set_result(1)                   # scheduler-owned node
    finally:
        g.shutdown(wait=True)


# -- distributed graph over real processes -----------------------------------

def test_remote_spawn_chain_and_data_affinity(cluster):
    a = cluster.defer(build, 3, lane=Lane.PREFETCH, name="build")
    b = cluster.defer(double, a, name="double")
    assert (b.result()["x"] == 6).all()
    # the dependent followed its input's locality (data affinity)
    assert a.home in (1, 2) and b.home == a.home


def test_pin_keeps_result_remote_and_cross_locality_fetch(cluster):
    pinned = cluster.defer(build, 7, locality=1, pin=True, name="pinned")
    ref = pinned.result()
    assert isinstance(ref, RemoteRef) and ref.owner == 1
    assert (cluster.fetch(ref)["x"] == 7).all()          # driver <- worker1
    far = cluster.defer(double, ref, locality=2, name="far")
    assert (far.result()["x"] == 14).all()               # worker2 <- worker1


def test_remote_error_poisons_only_dependents_and_locality_survives(cluster):
    bad = cluster.defer(boom, 9, locality=1, name="bad")
    dep = cluster._graph.defer(lambda x: x, bad, name="dep")
    sibling = cluster.defer(build, 1, locality=1, name="sibling")
    with pytest.raises(ValueError, match="poisoned batch 9"):
        dep.result(timeout=30)
    assert (sibling.result(timeout=30)["x"] == 1).all()
    after = cluster.defer(build, 2, locality=1, name="after")
    assert (after.result(timeout=30)["x"] == 2).all()    # locality alive


def test_upstream_poison_settles_undispatched_remote_task(cluster):
    """A distributed task whose dependency fails BEFORE dispatch must
    still settle (with the original error) - a stranded promise would
    hang barrier/shutdown forever."""
    bad = cluster.defer(boom, 4, locality=1, name="upstream")
    downstream = cluster.defer(double, bad, name="downstream")
    with pytest.raises(ValueError, match="poisoned batch 4"):
        downstream.result(timeout=30)
    cluster.barrier(timeout=30)          # nothing left outstanding
    assert cluster.stats()["outstanding"] == 0


def test_cancel_before_dispatch_releases_record(cluster):
    gate = cluster.defer(slow_mul, 1, locality=1, name="gate")
    dep = cluster.defer(double, gate, name="dep-gated")
    cluster.cancel(dep)                  # before its dispatch node ran
    with pytest.raises(CancelledError):
        dep.result(timeout=30)
    assert gate.result(timeout=30) == 10
    cluster.barrier(timeout=30)
    assert cluster.stats()["outstanding"] == 0


def test_cancellation_across_the_wire(cluster):
    # worker graphs have 2 threads: occupy both, then cancel the queued one
    s1 = cluster.defer(slow_mul, 1, locality=1, name="slow1")
    s2 = cluster.defer(slow_mul, 2, locality=1, name="slow2")
    s3 = cluster.defer(slow_mul, 3, locality=1, name="slow3")
    time.sleep(0.1)
    cluster.cancel(s3)
    with pytest.raises(CancelledError):
        s3.result(timeout=30)
    assert s1.result(timeout=30) == 10 and s2.result(timeout=30) == 20


def test_prefetcher_remote_poison_kills_only_that_batch(cluster):
    pf = Prefetcher(FlakyStream(poison_step=1), shardings=None, depth=2,
                    graph=cluster._graph, dgraph=cluster)
    try:
        assert (pf.get(0)["tokens"] == 0).all()
        with pytest.raises(ValueError, match="poisoned batch 1"):
            pf.get(1)
        assert (pf.get(2)["tokens"] == 2).all()          # stream continues
    finally:
        pf.close()


def test_pin_honored_on_driver_placement(cluster):
    """pin=True must yield a RemoteRef regardless of where placement
    lands - including the driver-local fast path."""
    fut = cluster.defer(build, 8, locality=0, pin=True, name="pin-local")
    ref = fut.result(timeout=30)
    assert isinstance(ref, RemoteRef) and ref.owner == 0
    assert (cluster.fetch(ref)["x"] == 8).all()


def test_foreign_graph_dependency_raises_and_leaves_nothing_behind(cluster):
    other = FuturizedGraph(max_workers=1, name="other")
    try:
        foreign = other.defer(lambda: 1)
        with pytest.raises(ValueError, match="different graph"):
            cluster.defer(double, foreign, locality=1, name="foreign")
        cluster.barrier(timeout=30)      # no stranded promise/record
        assert cluster.stats()["outstanding"] == 0
    finally:
        other.shutdown(wait=True)


def test_replicate_checksum_vote_across_localities(cluster):
    fut = cluster.replicate(build, 5, n=2, name="rep")
    assert (fut.result(timeout=30)["x"] == 5).all()


def test_unpicklable_function_fails_cleanly(cluster):
    fut = cluster.defer(lambda: 1, locality=1, name="closure")
    with pytest.raises(RuntimeError, match="not picklable"):
        fut.result(timeout=30)


def test_remote_stats_visible_from_driver(cluster):
    cluster.defer(build, 1, locality=1, name="warm").result(timeout=30)
    st = cluster.remote_stats(1)
    assert st["completed"] >= 1
    assert st["lane_time_hist"]["labels"][0] == "<100us"


def test_worker_loss_respawns_in_flight_tasks():
    dg = DistributedGraph(localities=3, name="kill-drill")
    try:
        futs = [dg.defer(slow_mul, i, locality=2, name=f"r{i}")
                for i in range(3)]
        time.sleep(0.1)                  # let the first task start
        dg.group.kill(2)
        assert [f.result(timeout=60) for f in futs] == [0, 10, 20]
        st = dg.stats()
        assert st["respawned"] >= 1 and st["alive_workers"] == [1]
    finally:
        dg.shutdown()


# -- Session parity -----------------------------------------------------------

def _plan(**kw):
    kw.setdefault("arch", ARCH)
    kw.setdefault("batch", 4)
    kw.setdefault("seq", 16)
    return Plan(**kw)


def test_session_serve_parity_single_vs_multi_locality():
    kw = dict(requests=4, slots=2, prompt_len=16, gen_len=4, verbose=False)
    with _plan().compile() as single:
        ref = single.serve(**kw)
    with _plan(localities=2).compile() as multi:
        out = multi.serve(**kw)
        dstats = out["runtime_stats"]["distributed"]
    assert out["tokens"] == ref["tokens"] and out["requests"] == 4
    decode = [n for n in out["nodes"] if n.startswith("decode:")]
    assert decode == [n for n in ref["nodes"] if n.startswith("decode:")]
    assert dstats["dispatched"].get(1, 0) > 0    # waves really went remote


def test_session_train_two_localities_matches_single_even_killed():
    """The acceptance drill: a 2-locality run (with a worker SIGKILLed
    mid-run!) produces the same loss as the single-process run - remote
    prefetch changes where batches are built, never what they contain."""
    with _plan().compile() as single:
        ref = single.train(steps=6, log_every=3, verbose=False)
    with _plan(localities=2).compile() as multi:
        out = multi.train(steps=6, log_every=3, kill_locality_at_step=3,
                          verbose=False)
        dstats = out["runtime_stats"]["distributed"]
    assert abs(out["final_loss"] - ref["final_loss"]) < 1e-4
    assert dstats["dispatched"].get(1, 0) > 0
    assert dstats["alive_workers"] == []         # the drill really killed it
