"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mamba2_scan import mamba2_chunk_scan
from repro.kernels.onebit import onebit_dequantize, onebit_quantize


@pytest.mark.parametrize("B,H,Hkv,S,d", [
    (1, 4, 2, 256, 64),
    (2, 8, 8, 128, 32),
    (1, 4, 1, 256, 64),
    (1, 2, 2, 512, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(B, H, Hkv, S, d, dtype, causal):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, S, d), dtype)
    k = jax.random.normal(ks[1], (B, Hkv, S, d), dtype)
    v = jax.random.normal(ks[2], (B, Hkv, S, d), dtype)
    out = flash_attention(q, k, v, causal=causal, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol,
                               rtol=tol)


def test_flash_attention_window_and_blocks():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 2, 512, 64))
    k = jax.random.normal(ks[1], (1, 2, 512, 64))
    v = jax.random.normal(ks[2], (1, 2, 512, 64))
    want = ref.flash_attention_ref(q, k, v, causal=True, window=128)
    for bq, bk in [(128, 128), (256, 64), (64, 256)]:
        out = flash_attention(q, k, v, causal=True, window=128,
                              block_q=bq, block_kv=bk, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("B,H,L,P,N,chunk", [
    (1, 2, 256, 32, 16, 64),
    (2, 4, 128, 64, 64, 32),
    (1, 1, 512, 16, 8, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mamba2_kernel_sweep(B, H, L, P, N, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    xdt = jax.random.normal(ks[0], (B, H, L, P), dtype) * 0.5
    a = -jnp.abs(jax.random.normal(ks[1], (B, H, L))) * 0.1
    Bm = jax.random.normal(ks[2], (B, H, L, N), dtype) * 0.5
    Cm = jax.random.normal(ks[3], (B, H, L, N), dtype) * 0.5
    y, st = mamba2_chunk_scan(xdt, a, Bm, Cm, chunk=chunk, interpret=True)
    yr, str_ = ref.mamba2_scan_ref(xdt, a, Bm, Cm)
    tol = 6e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(st), np.asarray(str_), atol=tol,
                               rtol=tol)


@pytest.mark.parametrize("R,C,bm", [(256, 512, 128), (64, 128, 64),
                                    (128, 1024, 128)])
def test_onebit_kernel_roundtrip(R, C, bm):
    ks = jax.random.split(jax.random.PRNGKey(3), 2)
    g = jax.random.normal(ks[0], (R, C))
    e = jax.random.normal(ks[1], (R, C)) * 0.1
    packed, scale, err = onebit_quantize(g, e, block_rows=bm, interpret=True)
    deq = onebit_dequantize(packed, scale, block_rows=bm, interpret=True)
    signs_r, scale_r, err_r = ref.onebit_quantize_ref(g, e)
    deq_r = ref.onebit_dequantize_ref(signs_r, scale_r)
    np.testing.assert_allclose(np.asarray(deq), np.asarray(deq_r), atol=1e-6)
    np.testing.assert_allclose(np.asarray(err), np.asarray(err_r), atol=1e-6)
    # dequantized + error reconstructs the input exactly
    np.testing.assert_allclose(np.asarray(deq + err), np.asarray(g + e),
                               atol=1e-5)


def test_onebit_jnp_pack_matches_kernel_pack():
    from repro.optim import compression
    ks = jax.random.split(jax.random.PRNGKey(4), 2)
    g = jax.random.normal(ks[0], (64, 1024))
    e = jnp.zeros((64, 1024))
    packed_k, scale_k, _ = onebit_quantize(g, e, block_rows=64,
                                           interpret=True)
    signs = np.asarray(g) >= 0
    packed_j = compression.pack_bits(jnp.asarray(signs))
    np.testing.assert_array_equal(np.asarray(packed_k), np.asarray(packed_j))
    # unpack roundtrip
    np.testing.assert_array_equal(
        np.asarray(compression.unpack_bits(packed_j)), signs)
