"""Loop-aware HLO cost analysis: the roofline's source of truth."""
import jax
import jax.numpy as jnp

from helpers import run_devices
from repro.core import hlo_analysis, hlo_costs


def _costs(fn, *args):
    co = jax.jit(fn).lower(*args).compile()
    return hlo_costs.analyze(co.as_text(), 1)


def test_scan_flops_equal_unrolled():
    def one(x, w):
        return jnp.tanh(x @ w), None

    def scanned(x, ws):
        y, _ = jax.lax.scan(one, x, ws)
        return y

    def unrolled(x, ws):
        for i in range(12):
            x, _ = one(x, ws[i])
        return x

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((12, 256, 256), jnp.float32)
    cs = _costs(scanned, x, ws)
    cu = _costs(unrolled, x, ws)
    want = 12 * 2 * 128 * 256 * 256
    assert cs.flops == want
    assert cu.flops == want
    # byte models legitimately differ across program forms (loop-carried
    # state vs static slices); they must agree within 2x
    assert 0.5 < cs.bytes / cu.bytes < 2.0


def test_nested_scan_multiplies():
    def inner(x, w):
        return x @ w, None

    def outer(x, ws):
        def body(c, _):
            y, _ = jax.lax.scan(inner, c, ws)
            return y, None
        y, _ = jax.lax.scan(body, x, None, length=5)
        return y

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((3, 64, 64), jnp.float32)
    c = _costs(outer, x, ws)
    assert c.flops == 5 * 3 * 2 * 64 * 64 * 64


def test_dot_flops_with_batch_dims():
    def f(a, b):
        return jnp.einsum("bij,bjk->bik", a, b)
    a = jax.ShapeDtypeStruct((4, 32, 16), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 16, 8), jnp.float32)
    c = _costs(f, a, b)
    assert c.flops == 2 * 4 * 32 * 8 * 16


def test_collectives_inside_scan_are_multiplied():
    out = run_devices("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.core import hlo_costs
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((4,), ('d',))

        def body(x):
            def step(c, _):
                return jax.lax.psum(c, 'd'), None
            y, _ = jax.lax.scan(step, x, None, length=7)
            return y

        from repro.core.compat import shard_map
        f = jax.jit(shard_map(body, mesh=mesh, in_specs=P(),
                                  out_specs=P(), check_vma=False))
        co = f.lower(jax.ShapeDtypeStruct((128, 128), jnp.float32)).compile()
        c = hlo_costs.analyze(co.as_text(), 4)
        print('COUNT', c.coll_counts.get('all-reduce', 0))
        print('WIRE', c.total_wire_bytes)
    """, n_devices=4)
    count = float(out.split("COUNT", 1)[1].split()[0])
    wire = float(out.split("WIRE", 1)[1].split()[0])
    assert count == 7
    want = 7 * 2 * (128 * 128 * 4) * 3 / 4   # 7 ring all-reduces
    assert abs(wire - want) / want < 0.01


def test_wire_byte_model_all_gather():
    txt = '''
ENTRY %main (p: f32[64,128]) -> f32[256,128] {
  %p = f32[64,128]{1,0} parameter(0)
  ROOT %ag = f32[256,128]{1,0} all-gather(%p), replica_groups=[1,4]<=[4], dimensions={0}
}
'''
    c = hlo_costs.analyze(txt, 4)
    s = 256 * 128 * 4
    assert abs(c.total_wire_bytes - s * 3 / 4) < 1
    assert c.coll_counts["all-gather"] == 1


def test_shape_bytes_parses_tuples_and_dtypes():
    assert hlo_analysis._shape_bytes("(f32[2,3], bf16[4])") == 24 + 8
    assert hlo_analysis._shape_bytes("pred[8]") == 8
    assert hlo_analysis._shape_bytes("u32[2,2]{1,0}") == 16


def test_dynamic_slice_counts_window_not_buffer():
    def f(stack, i):
        return jax.lax.dynamic_index_in_dim(stack, i, 0, keepdims=False)
    stack = jax.ShapeDtypeStruct((100, 128, 128), jnp.float32)
    c = _costs(f, stack, jax.ShapeDtypeStruct((), jnp.int32))
    # window is 64KB; full buffer is 6.4MB - must count ~window-sized traffic
    assert c.bytes < 1e6, c.bytes


def test_dus_rooted_fusion_counts_update_not_buffer():
    """Regression (xlstm §Perf C2 investigation): a fusion whose root is a
    dynamic-update-slice must count the updated row, not the whole aliased
    buffer."""
    txt = '''
%fused_dus (param_0: f32[100,64], param_1: f32[1,64], param_2: s32[]) -> f32[100,64] {
  %param_0 = f32[100,64]{1,0} parameter(0)
  %param_1 = f32[1,64]{1,0} parameter(1)
  %param_2 = s32[] parameter(2)
  %c = s32[] constant(0)
  ROOT %dus = f32[100,64]{1,0} dynamic-update-slice(%param_0, %param_1, %param_2, %c)
}

ENTRY %main (a: f32[100,64], b: f32[1,64], i: s32[]) -> f32[100,64] {
  %a = f32[100,64]{1,0} parameter(0)
  %b = f32[1,64]{1,0} parameter(1)
  %i = s32[] parameter(2)
  ROOT %f = f32[100,64]{1,0} fusion(%a, %b, %i), kind=kLoop, calls=%fused_dus
}
'''
    c = hlo_costs.analyze(txt, 1)
    # 3x the 256-byte row (update read + window read/write), NOT ~51 KB
    assert c.bytes < 2048, c.bytes


def test_fusion_param_sliced_inside_counts_window():
    """The scan-over-layers pattern: a fusion that only dynamic-slices a
    stacked parameter buffer reads one layer's slice, not the stack."""
    txt = '''
%fused_ds (param_0: f32[48,1024], param_1: s32[]) -> f32[1,1024] {
  %param_0 = f32[48,1024]{1,0} parameter(0)
  %param_1 = s32[] parameter(1)
  %c = s32[] constant(0)
  ROOT %ds = f32[1,1024]{1,0} dynamic-slice(%param_0, %param_1, %c), dynamic_slice_sizes={1,1024}
}

ENTRY %main (a: f32[48,1024], i: s32[]) -> f32[1,1024] {
  %a = f32[48,1024]{1,0} parameter(0)
  %i = s32[] parameter(1)
  ROOT %f = f32[1,1024]{1,0} fusion(%a, %i), kind=kLoop, calls=%fused_ds
}
'''
    c = hlo_costs.analyze(txt, 1)
    assert c.bytes < 3 * 4096 + 64, c.bytes  # window-sized, not 192 KB
