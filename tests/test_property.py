"""Property-based tests on the system's invariants: tensor-fusion
pack/unpack, tiling-plan divisibility, grain policy bounds, 1-bit
compression error feedback, checkpoint shard-assignment ownership, and
manifest/format encode-decode round-trips.

Runs under real ``hypothesis`` when installed (CI installs it) and
falls back to ``tests/_property_fallback.py`` - a deterministic seeded
N-example runner over the same strategies - otherwise, so this suite
NEVER silently skips."""
import tempfile
import time
from pathlib import Path

import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # pragma: no cover - CI has it
    from _property_fallback import given, settings, strategies as st

from repro.checkpoint import format as ckfmt
from repro.core import fusion
from repro.core.granularity import GrainPolicy
from repro.core.sharding import DEFAULT_RULES, ShardingRules, spec_for
from repro.launch.mesh import make_local_mesh

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")

_shapes = st.lists(
    st.tuples(st.integers(1, 6), st.integers(1, 64), st.integers(1, 8)),
    min_size=1, max_size=12)


@given(shapes=_shapes, cap=st.integers(64, 1 << 16),
       pad=st.sampled_from([1, 4, 8, 32]))
def test_fusion_roundtrip_any_shapes(shapes, cap, pad):
    tree = {f"p{i}": np.arange(int(np.prod(s)), dtype=np.float32).reshape(s)
            + i for i, s in enumerate(shapes)}
    plan = fusion.make_plan(tree, cap_bytes=cap, pad_to=pad)
    bufs = fusion.pack(tree, plan)
    # every bucket respects padding divisibility
    for buf, b in zip(bufs, plan.buckets):
        assert buf.shape[0] % pad == 0
        assert buf.shape[0] == b.size
    back = fusion.unpack(bufs, plan)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(back[k]), tree[k])


@given(shapes=_shapes)
def test_fusion_preserves_flatten_order(shapes):
    """Entries inside buckets must keep flatten order (overlap property)."""
    tree = [np.zeros(s, np.float32) for s in shapes]
    plan = fusion.make_plan(tree, cap_bytes=1 << 12)
    seen = []
    for b in plan.buckets:
        seen.extend(e.index for e in b.entries)
    # per-dtype order is ascending; single dtype here -> globally ascending
    assert seen == sorted(seen)


@given(mixed=st.lists(st.sampled_from(["f32", "i32", "bf16"]), min_size=1,
                      max_size=8))
def test_fusion_buckets_are_dtype_homogeneous(mixed):
    dt = {"f32": np.float32, "i32": np.int32, "bf16": jnp.bfloat16}
    tree = [jnp.zeros((7,), dt[m]) for m in mixed]
    plan = fusion.make_plan(tree, cap_bytes=1 << 20)
    for b in plan.buckets:
        dts = {jnp.dtype(dt[mixed[e.index]]) for e in b.entries}
        assert len(dts) == 1


@given(dims=st.lists(st.sampled_from(
    ["batch", "seq", "heads", "kv_heads", "d_ff", "vocab", "embed", None]),
    min_size=1, max_size=4),
    sizes=st.lists(st.integers(1, 512), min_size=4, max_size=4))
def test_spec_for_only_shards_divisible_dims(dims, sizes):
    mesh = make_local_mesh(data=1, model=1)  # 1-device: everything replicates
    rules = ShardingRules(DEFAULT_RULES)
    shape = tuple(sizes[:len(dims)])
    spec = spec_for(mesh, rules, shape, tuple(dims))
    # on a 1-device mesh every dim must be replicated
    assert all(p is None for p in spec)


@given(n_params=st.integers(1 << 16, 1 << 34),
       dp=st.sampled_from([1, 2, 8, 16, 32]),
       batch=st.sampled_from([8, 64, 256]))
def test_grain_policy_bounds(n_params, dp, batch):
    dec = GrainPolicy.derive(n_params=n_params, n_tensors=50,
                             global_batch=batch, seq=1024, d_model=1024,
                             n_layers=12, head_dim=64, dp_degree=dp)
    assert 1 <= dec.n_microbatches <= max(batch // max(dp, 1), 1)
    assert dec.bucket_bytes >= 1
    if dp > 1:
        assert dec.bucket_bytes <= 64 << 20 or \
            dec.bucket_bytes >= n_params  # tiny models: single bucket ok
    assert dec.attn_block_q % 8 == 0
    assert dec.remat in ("none", "block", "full")


@given(seed=st.integers(0, 2 ** 16), rows=st.sampled_from([2, 4, 8]))
def test_onebit_error_feedback_is_lossless_in_aggregate(seed, rows):
    """EF invariant: deq + new_err == g + old_err exactly (no signal lost)."""
    from repro.kernels.ref import onebit_dequantize_ref, onebit_quantize_ref
    rng = np.random.default_rng(seed)
    g = rng.standard_normal((rows, 64)).astype(np.float32)
    e = rng.standard_normal((rows, 64)).astype(np.float32) * 0.5
    signs, scale, e2 = onebit_quantize_ref(jnp.asarray(g), jnp.asarray(e))
    deq = onebit_dequantize_ref(signs, scale)
    np.testing.assert_allclose(np.asarray(deq + e2), g + e, atol=1e-5)


@given(seed=st.integers(0, 2 ** 16))
def test_checksum_detects_any_bitflip(seed):
    from repro.core.resilience import tree_checksum
    rng = np.random.default_rng(seed)
    tree = {"a": rng.standard_normal((4, 5)).astype(np.float32),
            "b": rng.integers(0, 100, (3,)).astype(np.int32)}
    c1 = tree_checksum(tree)
    flip = dict(tree)
    a = tree["a"].copy()
    a_view = a.view(np.uint32).reshape(-1)
    a_view[rng.integers(0, a_view.size)] ^= np.uint32(1 << int(rng.integers(0, 32)))
    flip["a"] = a
    assert tree_checksum(flip) != c1


def test_exchange_phylanx_fuse_mask_partitions_correctly():
    """Sharding-aware fusion (§Perf A2): masked-out leaves bypass buckets
    but every leaf still comes back with its own value (identity fn)."""
    from repro.core import overlap

    tree = {"big_sharded": jnp.arange(64.0).reshape(8, 8),
            "small_a": jnp.ones(3), "small_b": jnp.ones(5) * 2}
    mask = {"big_sharded": False, "small_a": True, "small_b": True}
    # monkey-style: run through the fusion path with pmean over zero axes
    # is impossible in-process (1 device), so check plan partitioning only
    from repro.core import fusion
    leaves = [v for k, v in sorted(tree.items()) if mask[k]]
    plan = fusion.make_plan(leaves, cap_bytes=1 << 20)
    assert plan.n_leaves == 2
    total = sum(b.total for b in plan.buckets)
    assert total == 8


# -- checkpoint shard assignment (ownership round-trip) -----------------------

@given(n_leaves=st.integers(0, 200), n_ranks=st.integers(1, 16),
       base=st.integers(0, 3))
def test_assign_shards_is_a_contiguous_total_partition(n_leaves, n_ranks,
                                                       base):
    """The ownership invariants restore relies on: shards cover every
    global leaf index exactly once, in order; each shard's block is
    contiguous; sizes are balanced; and when there are enough leaves
    EVERY locality owns a shard (the save-time world is fully used)."""
    ranks = list(range(base, base + n_ranks))
    shards = ckfmt.assign_shards(n_leaves, ranks)
    covered = [i for _, _, idx in shards for i in idx]
    assert covered == list(range(n_leaves))          # total + disjoint
    for sid, (shard_id, rank, idx) in enumerate(shards):
        assert shard_id == sid                       # dense shard ids
        assert idx == list(range(idx[0], idx[0] + len(idx)))  # contiguous
        assert rank in ranks
    sizes = [len(idx) for _, _, idx in shards]
    assert not sizes or max(sizes) - min(sizes) <= 1  # balanced
    if n_leaves >= n_ranks:
        assert [r for _, r, _ in shards] == ranks     # covers ALL ranks


# -- manifest / format encode-decode round-trips ------------------------------

_ckpt_shapes = st.lists(st.tuples(st.integers(1, 4), st.integers(1, 6)),
                        min_size=1, max_size=6)


@settings(max_examples=10, deadline=None)
@given(shapes=_ckpt_shapes, n_ranks=st.integers(1, 4),
       seed=st.integers(0, 999))
def test_format_manifest_encode_decode_roundtrip(shapes, n_ranks, seed):
    """save_shard -> build_manifest -> commit_manifest -> load_manifest
    -> read_shard_segments reproduces every leaf bit-for-bit, and the
    manifest's ownership/checksum schema is internally consistent."""
    rng = np.random.default_rng(seed)
    leaves = [rng.normal(size=s).astype(np.float32) for s in shapes]
    shards = ckfmt.assign_shards(len(leaves), list(range(n_ranks)))
    with tempfile.TemporaryDirectory() as d:
        tmp = Path(d) / ".tmp_step_00000001"
        entries = [ckfmt.save_shard(str(tmp), sid, idx,
                                    [leaves[i] for i in idx])
                   for sid, _rank, idx in shards]
        manifest = ckfmt.build_manifest(step=1, treedef="t",
                                        n_leaves=len(leaves),
                                        shards=entries)
        final = ckfmt.commit_manifest(tmp, Path(d) / "step_00000001",
                                      manifest)
        m2 = ckfmt.load_manifest(final)
        assert m2["format"] == ckfmt.FORMAT_VERSION
        assert m2["n_shards"] == len(entries)
        owned = sorted(s for ids in m2["ownership"].values() for s in ids)
        assert owned == [e["shard"] for e in m2["shards"]]
        got = {}
        for e in m2["shards"]:
            assert e["checksum"] == ckfmt.shard_checksum(
                leaf["checksum"] for leaf in e["leaves"])
            for seg in ckfmt.read_shard_segments(str(final), e):
                assert seg["slice"] is None          # whole-leaf shards
                got[seg["index"]] = seg["array"]
        assert sorted(got) == list(range(len(leaves)))
        for i, leaf in enumerate(leaves):
            np.testing.assert_array_equal(got[i], leaf)


@settings(max_examples=10, deadline=None)
@given(rows=st.integers(2, 12), cols=st.integers(1, 5),
       n_cuts=st.integers(0, 3), seed=st.integers(0, 999))
def test_sliced_segments_roundtrip_and_assemble(rows, cols, n_cuts, seed):
    """The SPMD path's leaf splitting: a leaf saved as arbitrary
    contiguous row-slices (across MULTIPLE shard files, like multiple
    hosts) assembles back bit-for-bit via read_shard_segments +
    assemble_leaf."""
    rng = np.random.default_rng(seed)
    leaf = rng.normal(size=(rows, cols)).astype(np.float32)
    cuts = sorted({int(c) for c in rng.integers(1, rows, size=n_cuts)})
    bounds = [0] + cuts + [rows]
    pieces = [(lo, hi) for lo, hi in zip(bounds, bounds[1:])]
    with tempfile.TemporaryDirectory() as d:
        entries = []
        for sid, (lo, hi) in enumerate(pieces):     # one "host" each
            entries.append(ckfmt.save_shard(
                d, sid, [0], [leaf[lo:hi]],
                slices=[([(lo, hi), (0, cols)], [rows, cols])]))
        segs = [seg for e in entries
                for seg in ckfmt.read_shard_segments(d, e)]
        back = ckfmt.assemble_leaf(0, segs)
        np.testing.assert_array_equal(back, leaf)


def test_zero1_scatter_mask_rules():
    """dim0 must divide dp, not be model-claimed, and be big enough."""
    import jax.numpy as jnp
    from repro.core import overlap
    from repro.core.sharding import ParamSpec, default_rules
    from repro.launch.mesh import make_local_mesh
    mesh = make_local_mesh(data=1, model=1)   # ndp=1 -> nothing scatters
    specs = {"w": ParamSpec((48, 1024, 1024), ("layers", "embed", "d_ff")),
             "b": ParamSpec((7,), ("embed",))}
    mask = overlap.zero1_scatter_mask(specs, mesh, default_rules(), ndp=1)
    assert mask == {"w": False, "b": False}
    mask16 = overlap.zero1_scatter_mask(specs, mesh, default_rules(), ndp=16)
    from repro.core import compat
    if compat.NEEDS_DP_OPERAND_REPLICATION:
        # old jax: the scatter path is disabled wholesale (see overlap.py)
        assert mask16 == {"w": False, "b": False}
    else:
        assert mask16["w"] is True      # 48 % 16 == 0, big, dim0 free
        assert mask16["b"] is False     # too small / indivisible


# -- DDP gradient wire (DESIGN.md §11) ----------------------------------------

@given(n=st.integers(1, 300), seed=st.integers(0, 2 ** 16))
def test_pack_signs_roundtrip_any_length(n, seed):
    """pack_signs/unpack_signs round-trip at EVERY length, including
    lengths that are not a multiple of 8 (or 32): zero-padded
    little-endian uint32 words, exact bit recovery."""
    from repro.optim import compression
    rng = np.random.default_rng(seed)
    bits = rng.random(n) < 0.5
    packed = compression.pack_signs(jnp.asarray(bits))
    assert packed.shape == ((n + 31) // 32,)
    assert packed.dtype == jnp.uint32
    back = compression.unpack_signs(packed, n)
    np.testing.assert_array_equal(np.asarray(back), bits)


@given(rows=st.integers(1, 4), words=st.integers(1, 3),
       seed=st.integers(0, 2 ** 16))
def test_pack_bits_roundtrip_2d(rows, words, seed):
    """The 2-D [R, C] face used by quantize_bucket: 32 bits per uint32
    word, row layout preserved."""
    from repro.optim import compression
    rng = np.random.default_rng(seed)
    signs = rng.random((rows, 32 * words)) < 0.5
    packed = compression.pack_bits(jnp.asarray(signs))
    assert packed.shape == (rows, words)
    back = compression.unpack_bits(packed)
    np.testing.assert_array_equal(np.asarray(back), signs)


@given(rows=st.integers(1, 3), seed=st.integers(0, 2 ** 16))
def test_quantize_bucket_sign_fidelity_and_scale_bounds(rows, seed):
    """Dequantized values are EXACTLY +/- the per-row L1 scale with the
    sign of the input, and 0 <= scale = mean|q| <= max|q| per row."""
    from repro.optim import compression
    rng = np.random.default_rng(seed)
    n = rows * compression.ROW
    g = rng.standard_normal(n).astype(np.float32)
    err0 = jnp.zeros((rows, compression.ROW), jnp.float32)
    packed, scale, _ = compression.quantize_bucket(jnp.asarray(g), err0)
    q = g.reshape(rows, compression.ROW)
    s = np.asarray(scale)
    assert (s >= 0).all()
    assert (s.ravel() <= np.abs(q).max(axis=1) + 1e-6).all()
    np.testing.assert_allclose(s.ravel(), np.abs(q).mean(axis=1), rtol=1e-5)
    deq = np.asarray(compression.dequantize_bucket(packed, scale, n))
    np.testing.assert_array_equal(deq.reshape(rows, -1),
                                  np.where(q >= 0, s, -s))


@given(rows=st.integers(1, 2), seed=st.integers(0, 2 ** 16))
def test_quantize_bucket_error_feedback_invariant(rows, seed):
    """EF invariant: dequant(quant(g + e)) + e' == g + e at float
    tolerance - quantization error is never lost, only delayed."""
    from repro.optim import compression
    rng = np.random.default_rng(seed)
    n = rows * compression.ROW
    g = rng.standard_normal(n).astype(np.float32)
    e = (0.5 * rng.standard_normal((rows, compression.ROW))
         ).astype(np.float32)
    packed, scale, e2 = compression.quantize_bucket(
        jnp.asarray(g), jnp.asarray(e))
    deq = np.asarray(compression.dequantize_bucket(packed, scale, n))
    np.testing.assert_allclose(deq + np.asarray(e2).ravel(),
                               g + np.asarray(e).ravel(), atol=1e-5)


# -- elastic membership (DESIGN.md §13) ---------------------------------------

@given(n=st.integers(0, 120), owner=st.integers(0, 3),
       n_new=st.integers(1, 4), seed=st.integers(0, 999))
def test_rebalance_plan_is_total_contiguous_balanced(n, owner, n_new, seed):
    """AGAS rebalance math: moved blocks are drawn from the owner's
    sorted live indices without loss or duplication, the owner keeps a
    PREFIX, every newcomer's block is contiguous, block sizes are
    balanced (spread <= 1), and with enough objects every newcomer
    adopts something."""
    from repro.distrib import rebalance_plan
    rng = np.random.default_rng(seed)
    indices = [int(i) for i in rng.choice(6 * n + 6, size=n, replace=False)]
    newcomers = [owner + 1 + i for i in range(n_new)]
    plan = rebalance_plan(indices, owner, newcomers)
    srt = sorted(indices)
    pos = {idx: k for k, idx in enumerate(srt)}
    moved = [i for blk in plan.values() for i in blk]
    assert set(plan) <= set(newcomers)
    assert len(moved) == len(set(moved))                  # no dup moves
    assert set(moved) <= set(indices)                     # no inventions
    kept = [i for i in srt if i not in set(moved)]
    assert kept == srt[:len(kept)]                        # owner keeps prefix
    for blk in plan.values():
        ps = [pos[i] for i in blk]
        assert ps == list(range(ps[0], ps[0] + len(ps)))  # contiguous block
    sizes = [len(kept)] + [len(b) for b in plan.values()]
    if n >= n_new + 1:
        assert max(sizes) - min(sizes) <= 1               # balanced
        assert set(plan) == set(newcomers)                # everyone adopts
    assert len(kept) + len(moved) == n                    # total partition


@settings(max_examples=10, deadline=None)
@given(n=st.integers(1, 12), seed=st.integers(0, 999))
def test_forwarding_stub_deref_equals_direct_deref(n, seed):
    """Migration transparency: after ``rebalance`` moves a block to a
    newcomer, every STALE ref fetched through its forwarding stub
    yields exactly the value a direct (pre-migration) deref did."""
    from repro.distrib import ObjectDirectory
    from repro.distrib.messaging import Endpoint
    a, b = Endpoint(0), Endpoint(1)
    try:
        da, db = ObjectDirectory(0, a), ObjectDirectory(1, b)
        a.address_book[1] = b.address
        b.address_book[0] = a.address
        rng = np.random.default_rng(seed)
        vals = [rng.standard_normal((3,)).astype(np.float32)
                for _ in range(n)]
        refs = [da.put(v, summary=f"v{i}") for i, v in enumerate(vals)]
        direct = [np.asarray(da.fetch(r)) for r in refs]
        moved = da.rebalance([1])
        assert moved == n - (n + 1) // 2          # owner keeps first block
        assert len(db) == moved                   # newcomer adopted them
        for ref, before in zip(refs, direct):
            np.testing.assert_array_equal(np.asarray(da.fetch(ref)), before)
        aud = da.audit()
        assert aud["migrated"] == moved
        assert aud["forwarded_fetches"] == moved  # one chase per moved gid
    finally:
        a.close()
        b.close()


# -- paged inference cache (DESIGN.md §14) ------------------------------------

@given(seed=st.integers(0, 10 ** 6), n_events=st.integers(1, 60),
       page_bytes=st.sampled_from([8, 64, 256]))
def test_page_pool_event_soup_invariants(seed, n_events, page_bytes):
    """Seeded alloc/free/retire soup over ``PagePool``: after EVERY event
    no page is owned by two live owners, freed pages are reused before
    the pool grows, and the pool's books (allocs/frees/live/size) stay
    consistent with the test's own shadow ledger."""
    import random as _random
    from repro.core.paging import PageError, PagePool

    rng = _random.Random(seed)
    pool = PagePool(page_bytes)
    held: dict = {}                          # owner -> [page ids]

    def do_alloc():
        owner = f"r{rng.randrange(8)}"
        held.setdefault(owner, []).extend(
            pool.alloc(owner, rng.randint(0, 3)))

    def do_free():
        owners = [o for o, ps in held.items() if ps]
        if not owners:
            return
        owner = rng.choice(owners)
        k = rng.randint(1, len(held[owner]))
        batch = [held[owner].pop() for _ in range(k)]
        pool.free(batch, owner)

    def do_retire():                         # retire = free everything held
        owners = [o for o, ps in held.items() if ps]
        if not owners:
            return
        owner = rng.choice(owners)
        pool.free(held.pop(owner), owner)

    for _ in range(n_events):
        rng.choice([do_alloc, do_alloc, do_free, do_retire])()
        owners = pool.owners()
        mine = {p: o for o, ps in held.items() for p in ps}
        assert owners == mine                # single ownership, no leaks
        assert pool.live == len(mine)
        assert pool.size >= pool.live
        assert pool.allocs == pool.grown + pool.reused
        assert pool.allocs - pool.frees == pool.live

    # LIFO reuse: with the whole pool free, an alloc must NOT grow it
    for owner in list(held):
        pool.free(held.pop(owner), owner)
    size_before, grown_before = pool.size, pool.grown
    got = pool.alloc("reuser", min(3, size_before))
    assert pool.grown == grown_before        # reused, not grown
    assert pool.size == size_before
    for pid in got:                          # and reused pages are scrubbed
        assert not pool.read(pid, "reuser").any()
    # accounting violations raise, never corrupt
    if got:
        try:
            pool.free(got, "somebody-else")
            raise AssertionError("foreign free must raise PageError")
        except PageError:
            pass
        pool.free(got, "reuser")
        try:
            pool.free(got, "reuser")
            raise AssertionError("double free must raise PageError")
        except PageError:
            pass


@given(seed=st.integers(0, 10 ** 6), page_bytes=st.sampled_from([16, 128]),
       n_cycles=st.integers(1, 8))
def test_inference_cache_put_get_drop_no_stale_state(seed, page_bytes,
                                                     n_cycles):
    """alloc->write->free->realloc never leaks stale state: across
    put/drop cycles that deliberately recycle pages, every ``get``
    reassembles ITS request's pytree bit-for-bit (distinct fill patterns
    per request) and a dropped rid stays gone."""
    from repro.core.paging import InferenceCache

    rng = np.random.default_rng(seed)
    icache = InferenceCache(page_bytes=page_bytes)
    for cycle in range(n_cycles):
        live = {}
        for r in range(rng.integers(1, 4)):
            rid = f"c{cycle}r{r}"
            state = {"conv": rng.integers(0, 255,
                                          (int(rng.integers(1, 5)), 3),
                                          dtype=np.uint8),
                     "ssm": (np.full((int(rng.integers(1, 7)),),
                                     cycle * 16 + r, np.float32),
                             np.arange(int(rng.integers(1, 9)),
                                       dtype=np.int32) + cycle)}
            icache.put(rid, state)
            live[rid] = state
        for rid, state in live.items():      # bit-identical round-trip
            back = icache.get(rid)
            np.testing.assert_array_equal(back["conv"], state["conv"])
            np.testing.assert_array_equal(back["ssm"][0], state["ssm"][0])
            np.testing.assert_array_equal(back["ssm"][1], state["ssm"][1])
        for rid in live:
            assert icache.drop(rid)
            assert icache.get(rid) is None   # gone means gone
    assert len(icache) == 0
    c = icache.counters()
    assert c["pages_live"] == 0              # everything reclaimed
    assert c["cache_hits"] == c["cache_puts"]
    # recycling happened across cycles iff there was more than one
    if n_cycles > 1 and c["page_allocs"]:
        assert c["pages_reused"] > 0


def _echo(x):
    return x


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10 ** 6))
def test_steal_protocol_exactly_once_under_seeded_churn(seed):
    """Driver-side exactly-once invariant under seeded interleavings of
    join / steal / kill / complete events: at every point each task id
    is held by AT MOST ONE live locality (no double spawn), every task
    executes exactly once, and every future resolves with its task's
    value.  Workers are simulated faithfully in-process: a spawn lands
    in a queue, a lease pops before the handoff (the victim's cancel),
    a dead rank's queue dies with it."""
    import random as _random
    from repro.distrib import DistributedGraph
    from repro.distrib.messaging import PeerLostError

    rng = _random.Random(seed)
    g = DistributedGraph(localities=1, elastic=True, name="churn-sim")
    try:
        queues: dict[int, dict] = {1: {}, 2: {}}   # rank -> {tid: spawn}
        dead: set = set()
        pending_handoffs: list = []                # delayed lease releases

        def fake_post(rank, action, payload=None):
            if rank in dead:
                raise PeerLostError(f"locality {rank} is dead (sim)")
            if action == "spawn" and rank in queues:
                tid = payload["tid"]
                # the worker-side dup drop (PHY106 seam): landing one
                # tid twice at one locality would double-execute
                assert tid not in queues[rank], \
                    f"task {tid} spawned twice at locality {rank}"
                queues[rank][tid] = payload

        g.endpoint.post = fake_post
        with g.group._cond:
            g.group._alive.update(queues)

        executed: dict = {}                        # tid -> value run with

        def holders(tid):
            return [r for r, q in queues.items()
                    if r not in dead and tid in q]

        def run_one():
            ranks = [r for r, q in queues.items() if r not in dead and q]
            if not ranks:
                return
            r = rng.choice(ranks)
            tid = rng.choice(sorted(queues[r]))
            p = queues[r].pop(tid)
            assert tid not in executed, f"{tid} executed twice"
            executed[tid] = p["args"][0]
            g._on_task_done(r, {"tid": tid, "status": "ok",
                                "value": p["args"][0]})

        def steal_once(force_current_gen=False):
            alive = [r for r in g.group.alive_workers() if r not in dead]
            if not alive:
                return
            thief = rng.choice(alive)
            gen = g.group.gen
            if not force_current_gen and rng.random() < 0.2:
                gen -= 1                           # stale membership view
            out = g._on_steal_request(thief, {"thief": thief, "gen": gen})
            victim = out.get("leased")
            if victim is None or victim in dead:
                return
            stealable = [t for t, p in queues[victim].items()
                         if p.get("steal")]
            if not stealable:
                return
            tid = rng.choice(stealable)
            queues[victim].pop(tid)                # the victim's cancel
            handoff = (victim, {"tid": tid, "thief": thief,
                                "victim": victim, "gen": out["gen"]})
            if rng.random() < 0.4:
                pending_handoffs.append(handoff)   # delivered later
            else:
                g._on_steal_handoff(*handoff)

        def kill_one():
            alive = [r for r in g.group.alive_workers() if r not in dead]
            if len(alive) < 2:
                return
            r = rng.choice(alive)
            dead.add(r)
            queues[r].clear()                      # its queue dies with it
            g._on_peer_lost(r)

        def join_one():
            # protocol-level join: a new rank becomes dispatchable and
            # the membership generation moves (fencing in-flight steals)
            r = max(queues) + 1
            queues[r] = {}
            with g.group._cond:
                g.group._alive.add(r)
            with g._lock:
                g.group.gen += 1

        N = 12
        futs = [g.defer(_echo, i, name=f"c{i}") for i in range(N)]
        deadline = time.time() + 30
        while time.time() < deadline and \
                sum(len(q) for q in queues.values()) < N:
            time.sleep(0.005)                      # dispatch nodes land
        assert sum(len(q) for q in queues.values()) == N

        events = [run_one] * 4 + [steal_once, kill_one, join_one]
        for _ in range(rng.randint(10, 40)):
            rng.choice(events)()
            for i in range(N):                     # the core invariant
                assert len(holders(f"t{i}")) <= 1
        deadline = time.time() + 30
        while g._outstanding and time.time() < deadline:
            while pending_handoffs:                # late lease releases:
                g._on_steal_handoff(*pending_handoffs.pop())  # fenced or
            run_one()                              # re-spawned, never lost
            steal_once(force_current_gen=True)
        assert not g._outstanding, f"stranded tasks: {list(g._outstanding)}"
        for i, f in enumerate(futs):
            assert f.result(timeout=10) == i
        assert all(v == int(t[1:]) for t, v in executed.items())
    finally:
        g.shutdown()


# -- serve replica routing (DESIGN.md §15) -----------------------------------

@given(seed=st.integers(0, 10 ** 6), replicas=st.integers(1, 4))
def test_replica_router_event_soup_never_double_assigns_or_strands(
        seed, replicas):
    """Seeded soup of assign / re-assign / release / kill / revive events
    over the gateway's ``ReplicaRouter``: every routed request sits on
    exactly one live replica, affinity holds while that replica lives
    (``assign`` is idempotent across retire/refill), ``kill`` hands back
    exactly its rids, and nothing is ever routed to a dead replica or
    stranded while any replica is alive."""
    from repro.frontend.gateway import ReplicaRouter

    rng = np.random.default_rng(seed)
    router = ReplicaRouter(replicas)
    routed: dict[str, int] = {}                  # the test's shadow copy
    next_rid = [0]

    def check():
        assert router.assignment == routed
        for rid, r in routed.items():
            assert r in router.live, f"{rid} routed to dead replica {r}"
        for r in range(replicas):                # loads are consistent
            assert router.load(r) == \
                sum(1 for v in routed.values() if v == r)

    def assign_new():
        rid = f"r{next_rid[0]}"
        next_rid[0] += 1
        r = router.assign(rid)
        assert r in router.live
        # least-loaded tie-to-lowest, computed against the shadow copy
        # *before* this assignment landed
        loads = {i: sum(1 for v in routed.values() if v == i)
                 for i in router.live}
        best = min(loads.values())
        assert r == min(i for i, n in loads.items() if n == best)
        routed[rid] = r

    def reassign_existing():
        if not routed:
            return
        rid = rng.choice(sorted(routed))
        assert router.assign(rid) == routed[rid]     # affinity: stays put

    def release_one():
        if not routed:
            return
        rid = rng.choice(sorted(routed))
        router.release(rid)
        del routed[rid]

    def kill_one():
        victim = int(rng.integers(0, replicas))
        victims = router.kill(victim)
        assert sorted(victims) == sorted(
            rid for rid, r in routed.items() if r == victim)
        if not router.live:                      # gateway's revive edge
            router.revive(victim)
            return
        for rid in victims:                      # migrate, as run() does
            routed[rid] = router.assign(rid)
            assert routed[rid] in router.live
            assert routed[rid] != victim

    def revive_one():
        router.revive(int(rng.integers(0, replicas)))

    ops = [assign_new, assign_new, reassign_existing, release_one,
           kill_one, revive_one]
    for _ in range(60):
        ops[int(rng.integers(0, len(ops)))]()
        check()
    # drain: while anything is live, nothing is stranded
    assert router.live
    for rid in sorted(routed):
        assert router.assign(rid) in router.live


@given(seed=st.integers(0, 10 ** 6), page_bytes=st.sampled_from([32, 256]))
def test_named_caches_share_pool_but_never_cross_ownership(seed,
                                                           page_bytes):
    """Per-replica pool ownership: two named caches over one shared
    ``PagePool`` tag pages ``R{i}:req:{rid}``, so one replica freeing or
    reading the other's pages raises ``PageError``; ``transfer`` (the
    replica-death migration edge) moves the state bit-identically, flips
    ownership, and leaks nothing."""
    from repro.core.paging import InferenceCache, PageError, PagePool

    rng = np.random.default_rng(seed)
    pool = PagePool(page_bytes)
    r0 = InferenceCache(pool, name="R0")
    r1 = InferenceCache(pool, name="R1")
    state = {"kv": rng.standard_normal((int(rng.integers(2, 6)), 4)
                                       ).astype(np.float32),
             "pos": np.arange(int(rng.integers(1, 9)), dtype=np.int32)}
    r0.put("rq", state)
    pages = [pid for pid, owner in pool.owners().items()
             if owner == "R0:req:rq"]
    assert pages and pool.live == len(pages)     # tagged by the owner cache

    # the foreign replica can neither free nor read those pages
    with np.testing.assert_raises(PageError):
        pool.free(pages, "R1:req:rq")
    with np.testing.assert_raises(PageError):
        pool.read(pages[0], "R1:req:rq")
    assert r1.get("rq") is None                  # and its cache misses

    # transfer: bit-identical adoption, ownership flipped, no leaks
    assert r0.transfer("rq", r1)
    assert "rq" not in r0 and "rq" in r1
    back = r1.get("rq")
    np.testing.assert_array_equal(back["kv"], state["kv"])
    np.testing.assert_array_equal(back["pos"], state["pos"])
    assert all(owner == "R1:req:rq" for owner in pool.owners().values())
    assert r0.counters()["cache_transfers_out"] == 1
    assert r1.counters()["cache_transfers_in"] == 1

    # transferring an absent rid is a recorded miss, not an error
    assert not r0.transfer("ghost", r1)
    r1.drop("rq")
    assert pool.live == 0 and pool.allocs == pool.frees
