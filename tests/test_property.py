"""Property-based tests (hypothesis) on the system's invariants:
tensor-fusion pack/unpack, tiling-plan divisibility, grain policy bounds,
1-bit compression error feedback."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the hypothesis package")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import fusion
from repro.core.granularity import GrainPolicy
from repro.core.sharding import DEFAULT_RULES, ShardingRules, spec_for
from repro.launch.mesh import make_local_mesh

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")

_shapes = st.lists(
    st.tuples(st.integers(1, 6), st.integers(1, 64), st.integers(1, 8)),
    min_size=1, max_size=12)


@given(shapes=_shapes, cap=st.integers(64, 1 << 16),
       pad=st.sampled_from([1, 4, 8, 32]))
def test_fusion_roundtrip_any_shapes(shapes, cap, pad):
    tree = {f"p{i}": np.arange(int(np.prod(s)), dtype=np.float32).reshape(s)
            + i for i, s in enumerate(shapes)}
    plan = fusion.make_plan(tree, cap_bytes=cap, pad_to=pad)
    bufs = fusion.pack(tree, plan)
    # every bucket respects padding divisibility
    for buf, b in zip(bufs, plan.buckets):
        assert buf.shape[0] % pad == 0
        assert buf.shape[0] == b.size
    back = fusion.unpack(bufs, plan)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(back[k]), tree[k])


@given(shapes=_shapes)
def test_fusion_preserves_flatten_order(shapes):
    """Entries inside buckets must keep flatten order (overlap property)."""
    tree = [np.zeros(s, np.float32) for s in shapes]
    plan = fusion.make_plan(tree, cap_bytes=1 << 12)
    seen = []
    for b in plan.buckets:
        seen.extend(e.index for e in b.entries)
    # per-dtype order is ascending; single dtype here -> globally ascending
    assert seen == sorted(seen)


@given(mixed=st.lists(st.sampled_from(["f32", "i32", "bf16"]), min_size=1,
                      max_size=8))
def test_fusion_buckets_are_dtype_homogeneous(mixed):
    dt = {"f32": np.float32, "i32": np.int32, "bf16": jnp.bfloat16}
    tree = [jnp.zeros((7,), dt[m]) for m in mixed]
    plan = fusion.make_plan(tree, cap_bytes=1 << 20)
    for b in plan.buckets:
        dts = {jnp.dtype(dt[mixed[e.index]]) for e in b.entries}
        assert len(dts) == 1


@given(dims=st.lists(st.sampled_from(
    ["batch", "seq", "heads", "kv_heads", "d_ff", "vocab", "embed", None]),
    min_size=1, max_size=4),
    sizes=st.lists(st.integers(1, 512), min_size=4, max_size=4))
def test_spec_for_only_shards_divisible_dims(dims, sizes):
    mesh = make_local_mesh(data=1, model=1)  # 1-device: everything replicates
    rules = ShardingRules(DEFAULT_RULES)
    shape = tuple(sizes[:len(dims)])
    spec = spec_for(mesh, rules, shape, tuple(dims))
    # on a 1-device mesh every dim must be replicated
    assert all(p is None for p in spec)


@given(n_params=st.integers(1 << 16, 1 << 34),
       dp=st.sampled_from([1, 2, 8, 16, 32]),
       batch=st.sampled_from([8, 64, 256]))
def test_grain_policy_bounds(n_params, dp, batch):
    dec = GrainPolicy.derive(n_params=n_params, n_tensors=50,
                             global_batch=batch, seq=1024, d_model=1024,
                             n_layers=12, head_dim=64, dp_degree=dp)
    assert 1 <= dec.n_microbatches <= max(batch // max(dp, 1), 1)
    assert dec.bucket_bytes >= 1
    if dp > 1:
        assert dec.bucket_bytes <= 64 << 20 or \
            dec.bucket_bytes >= n_params  # tiny models: single bucket ok
    assert dec.attn_block_q % 8 == 0
    assert dec.remat in ("none", "block", "full")


@given(seed=st.integers(0, 2 ** 16), rows=st.sampled_from([2, 4, 8]))
def test_onebit_error_feedback_is_lossless_in_aggregate(seed, rows):
    """EF invariant: deq + new_err == g + old_err exactly (no signal lost)."""
    from repro.kernels.ref import onebit_dequantize_ref, onebit_quantize_ref
    rng = np.random.default_rng(seed)
    g = rng.standard_normal((rows, 64)).astype(np.float32)
    e = rng.standard_normal((rows, 64)).astype(np.float32) * 0.5
    signs, scale, e2 = onebit_quantize_ref(jnp.asarray(g), jnp.asarray(e))
    deq = onebit_dequantize_ref(signs, scale)
    np.testing.assert_allclose(np.asarray(deq + e2), g + e, atol=1e-5)


@given(seed=st.integers(0, 2 ** 16))
def test_checksum_detects_any_bitflip(seed):
    from repro.core.resilience import tree_checksum
    rng = np.random.default_rng(seed)
    tree = {"a": rng.standard_normal((4, 5)).astype(np.float32),
            "b": rng.integers(0, 100, (3,)).astype(np.int32)}
    c1 = tree_checksum(tree)
    flip = dict(tree)
    a = tree["a"].copy()
    a_view = a.view(np.uint32).reshape(-1)
    a_view[rng.integers(0, a_view.size)] ^= np.uint32(1 << int(rng.integers(0, 32)))
    flip["a"] = a
    assert tree_checksum(flip) != c1


def test_exchange_phylanx_fuse_mask_partitions_correctly():
    """Sharding-aware fusion (§Perf A2): masked-out leaves bypass buckets
    but every leaf still comes back with its own value (identity fn)."""
    from repro.core import overlap
    import jax

    tree = {"big_sharded": jnp.arange(64.0).reshape(8, 8),
            "small_a": jnp.ones(3), "small_b": jnp.ones(5) * 2}
    mask = {"big_sharded": False, "small_a": True, "small_b": True}
    # monkey-style: run through the fusion path with pmean over zero axes
    # is impossible in-process (1 device), so check plan partitioning only
    from repro.core import fusion
    leaves = [v for k, v in sorted(tree.items()) if mask[k]]
    plan = fusion.make_plan(leaves, cap_bytes=1 << 20)
    assert plan.n_leaves == 2
    total = sum(b.total for b in plan.buckets)
    assert total == 8


def test_zero1_scatter_mask_rules():
    """dim0 must divide dp, not be model-claimed, and be big enough."""
    import jax.numpy as jnp
    from repro.core import overlap
    from repro.core.sharding import ParamSpec, default_rules
    from repro.launch.mesh import make_local_mesh
    mesh = make_local_mesh(data=1, model=1)   # ndp=1 -> nothing scatters
    specs = {"w": ParamSpec((48, 1024, 1024), ("layers", "embed", "d_ff")),
             "b": ParamSpec((7,), ("embed",))}
    mask = overlap.zero1_scatter_mask(specs, mesh, default_rules(), ndp=1)
    assert mask == {"w": False, "b": False}
    mask16 = overlap.zero1_scatter_mask(specs, mesh, default_rules(), ndp=16)
    from repro.core import compat
    if compat.NEEDS_DP_OPERAND_REPLICATION:
        # old jax: the scatter path is disabled wholesale (see overlap.py)
        assert mask16 == {"w": False, "b": False}
    else:
        assert mask16["w"] is True      # 48 % 16 == 0, big, dim0 free
        assert mask16["b"] is False     # too small / indivisible
