"""A minimal, deterministic stand-in for ``hypothesis`` so the property
suite RUNS (never silently skips) even where the real package is not
installed.  CI installs real hypothesis and gets shrinking + edge-case
heuristics; this fallback draws a fixed number of seeded random examples
per test - strictly weaker, but the invariants are still exercised.

Only the strategy surface the suite uses is implemented: ``integers``,
``lists``, ``tuples``, ``sampled_from``, ``booleans``.  ``@given``
generates ``max_examples`` (from the loaded settings profile) examples
with a per-test deterministic seed; a failing example is re-raised with
the drawn arguments attached to the assertion message.
"""
from __future__ import annotations

import functools
import hashlib
import random
from typing import Any, Callable


class SearchStrategy:
    """A strategy is just ``draw(rng) -> value`` here."""

    def __init__(self, draw: Callable[[random.Random], Any]):
        self._draw = draw

    def example_for(self, rng: random.Random) -> Any:
        return self._draw(rng)


class _Strategies:
    @staticmethod
    def integers(min_value: int, max_value: int) -> SearchStrategy:
        return SearchStrategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def booleans() -> SearchStrategy:
        return SearchStrategy(lambda rng: rng.random() < 0.5)

    @staticmethod
    def sampled_from(seq) -> SearchStrategy:
        seq = list(seq)
        return SearchStrategy(lambda rng: rng.choice(seq))

    @staticmethod
    def tuples(*strats: SearchStrategy) -> SearchStrategy:
        return SearchStrategy(
            lambda rng: tuple(s.example_for(rng) for s in strats))

    @staticmethod
    def lists(elements: SearchStrategy, *, min_size: int = 0,
              max_size: int = 10) -> SearchStrategy:
        def draw(rng):
            n = rng.randint(min_size, max_size)
            return [elements.example_for(rng) for _ in range(n)]
        return SearchStrategy(draw)


strategies = _Strategies()


class settings:
    """Profile registry compatible with the subset the suite uses:
    ``settings.register_profile`` / ``load_profile`` and
    ``@settings(max_examples=N)`` as a decorator."""

    _profiles: dict[str, dict] = {"default": {"max_examples": 25}}
    _current: dict = {"max_examples": 25}

    def __init__(self, max_examples: int = None, deadline=None, **_kw):
        self.overrides = {}
        if max_examples is not None:
            self.overrides["max_examples"] = max_examples

    def __call__(self, fn):
        fn._fallback_settings = self.overrides
        return fn

    @classmethod
    def register_profile(cls, name: str, max_examples: int = 25,
                         deadline=None, **_kw):
        cls._profiles[name] = {"max_examples": max_examples}

    @classmethod
    def load_profile(cls, name: str):
        cls._current = dict(cls._profiles[name])


def given(**strats: SearchStrategy):
    """N-example randomized runner: deterministic per test name, so a
    failure reproduces on re-run."""

    def deco(fn):
        @functools.wraps(fn)
        def run(*args, **kwargs):
            n = getattr(run, "_fallback_settings", {}).get(
                "max_examples", settings._current["max_examples"])
            seed = int.from_bytes(
                hashlib.blake2b(fn.__name__.encode(),
                                digest_size=8).digest(), "big")
            rng = random.Random(seed)
            for i in range(n):
                example = {k: s.example_for(rng) for k, s in strats.items()}
                try:
                    fn(*args, **example, **kwargs)
                except BaseException as e:
                    raise AssertionError(
                        f"{fn.__name__} failed on example {i}: "
                        f"{example!r}") from e
        # pytest must see a 0-arg signature, not the strategy params
        # (they would look like missing fixtures)
        del run.__wrapped__
        run.hypothesis_fallback = True
        return run

    return deco
