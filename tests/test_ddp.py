"""DDP over the active-message fabric (DESIGN.md §11).

The battery the tentpole is proven by:
  * codec units - fp32 bitwise round-trip, onebit vs the jnp reference,
    exact wire-format byte counts, error-feedback statefulness;
  * ring units - world-1 identity, a real 2-endpoint in-process ring
    (bitwise-identical sums on both ranks, exact wire accounting),
    abort/peer-loss/timeout semantics;
  * plan validation - the ``Plan(ddp=True)`` error surface;
  * multiproc drills (marked) - 2-locality fp32 runs BIT-IDENTICAL in
    loss to a 1-process run over the same shards, onebit converges
    within tolerance over 50 steps, ``grad_wire_bytes`` is asserted
    EXACTLY, and a locality killed mid-all-reduce aborts the run with
    ``LocalityLostError`` instead of hanging.
"""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import steps as steps_lib
from repro.core.steps import Strategy
from repro.distrib import (Endpoint, Fp32Codec, LocalityLostError,
                           OneBitCodec, RingAllReduce, get_codec)
from repro.frontend.ddp import shard_batch
from repro.frontend.plan import Plan
from repro.optim import compression

ARCH = "qwen2.5-3b"


def _plan(**kw):
    kw.setdefault("arch", ARCH)
    kw.setdefault("batch", 4)
    kw.setdefault("seq", 16)
    kw.setdefault("ddp", True)
    return Plan(**kw)


def _toy_plan(n=4096):
    """A small single-bucket FusionPlan (padded to ROW*32 = 32768)."""
    return compression.make_plan(
        [jax.ShapeDtypeStruct((n,), jnp.float32)], 1)


# -- codecs -------------------------------------------------------------------

def test_get_codec_unknown_raises():
    with pytest.raises(ValueError, match="unknown grad codec"):
        get_codec("fp16")


def test_fp32_codec_roundtrip_is_bitwise():
    plan = _toy_plan()
    rng = np.random.default_rng(0)
    bufs = [rng.standard_normal(b.size).astype(np.float32)
            for b in plan.buckets]
    codec = get_codec("fp32")
    codec.reset(plan)
    payloads = codec.encode(bufs)
    assert [len(p) for p in payloads] == [4 * b.size for b in plan.buckets]
    assert codec.wire_bytes(plan) == sum(4 * b.size for b in plan.buckets)
    for data, buf, b in zip(payloads, bufs, plan.buckets):
        np.testing.assert_array_equal(codec.decode(data, b), buf)


def test_onebit_codec_matches_jnp_reference_and_wire_format():
    plan = _toy_plan()
    b = plan.buckets[0]
    rng = np.random.default_rng(1)
    g = rng.standard_normal(b.size).astype(np.float32)
    codec = get_codec("onebit")
    codec.reset(plan)
    (payload,) = codec.encode([g])
    # wire format: size/8 bytes of sign words + one f32 scale per ROW
    rows = b.size // compression.ROW
    assert len(payload) == b.size // 8 + 4 * rows
    assert codec.wire_bytes(plan) == b.size // 8 + 4 * rows
    # decode == the jnp reference quantizer with zero error state
    packed, scale, _ = compression.quantize_bucket(
        jnp.asarray(g), jnp.zeros((rows, compression.ROW), jnp.float32))
    ref = np.asarray(compression.dequantize_bucket(packed, scale, b.size))
    np.testing.assert_array_equal(codec.decode(payload, b), ref)


def test_onebit_codec_error_feedback_is_stateful():
    """A second encode of the SAME gradient must differ: the residual of
    the first quantization is folded in (and a reset clears it)."""
    plan = _toy_plan()
    g = np.random.default_rng(2).standard_normal(
        plan.buckets[0].size).astype(np.float32)
    codec = get_codec("onebit")
    codec.reset(plan)
    first = codec.encode([g])[0]
    second = codec.encode([g])[0]
    assert first != second
    codec.reset(plan)
    assert codec.encode([g])[0] == first


# -- ring all-reduce ----------------------------------------------------------

def test_ring_world1_is_identity():
    plan = _toy_plan()
    ring = RingAllReduce(None, 1)
    ring.configure("fp32", plan)
    bufs = [np.arange(b.size, dtype=np.float32) for b in plan.buckets]
    summed, metas = ring.allreduce(0, bufs, meta={"loss": 1.5})
    for out, buf in zip(summed, bufs):
        np.testing.assert_array_equal(out, buf)
    assert metas == {0: {"loss": 1.5}}
    assert ring.wire_bytes == 0
    ring.deactivate()


def test_ring_requires_configure():
    with pytest.raises(RuntimeError, match="configure"):
        RingAllReduce(None, 1).allreduce(0, [])


def _two_rings(account=None):
    a, b = Endpoint(0), Endpoint(1)
    a.address_book[1] = b.address
    b.address_book[0] = a.address
    return a, b, RingAllReduce(a, 2, account=account), RingAllReduce(b, 2)


def test_ring_two_endpoints_bitwise_and_exact_accounting():
    """A real 2-rank ring over in-process endpoints: both ranks compute
    the SAME bitwise sum (origin-rank combine order), metas travel with
    bucket 0, and each rank's wire_bytes is exactly one codec encode."""
    counted = []
    a, b, ra, rb = _two_rings(account=counted.append)
    try:
        plan = _toy_plan()
        ra.configure("fp32", plan, gen=7)
        rb.configure("fp32", plan, gen=7)
        rng = np.random.default_rng(3)
        bufs = {r: [rng.standard_normal(bk.size).astype(np.float32)
                    for bk in plan.buckets] for r in (0, 1)}
        out = {}

        def run(ring):
            out[ring.rank] = ring.allreduce(
                5, bufs[ring.rank], meta={"rank": ring.rank}, timeout=30)

        t = threading.Thread(target=run, args=(rb,))
        t.start()
        run(ra)
        t.join(timeout=30)
        assert not t.is_alive()
        for i, bk in enumerate(plan.buckets):
            expect = bufs[0][i].copy() + bufs[1][i]   # rank order 0, 1
            np.testing.assert_array_equal(out[0][0][i], expect)
            np.testing.assert_array_equal(out[1][0][i], expect)
        assert out[0][1] == {0: {"rank": 0}, 1: {"rank": 1}}
        assert out[1][1] == out[0][1]
        per = Fp32Codec().wire_bytes(plan)
        assert ra.wire_bytes == per          # own encode, no relays at W=2
        assert rb.wire_bytes == per
        assert sum(counted) == per           # the account callback saw it
    finally:
        ra.deactivate(), rb.deactivate()
        a.close(), b.close()


def test_ring_onebit_sums_identically_on_both_ranks():
    a, b, ra, rb = _two_rings()
    try:
        plan = _toy_plan()
        ra.configure("onebit", plan, gen=1)
        rb.configure("onebit", plan, gen=1)
        rng = np.random.default_rng(4)
        bufs = {r: [rng.standard_normal(bk.size).astype(np.float32)
                    for bk in plan.buckets] for r in (0, 1)}
        out = {}

        def run(ring):
            out[ring.rank] = ring.allreduce(0, bufs[ring.rank], timeout=30)

        t = threading.Thread(target=run, args=(rb,))
        t.start()
        run(ra)
        t.join(timeout=30)
        for i in range(len(plan.buckets)):
            np.testing.assert_array_equal(out[0][0][i], out[1][0][i])
        per = OneBitCodec().wire_bytes(plan)
        assert ra.wire_bytes == per and rb.wire_bytes == per
        assert 16 * per <= Fp32Codec().wire_bytes(plan)
    finally:
        ra.deactivate(), rb.deactivate()
        a.close(), b.close()


def test_ring_abort_and_peer_lost_raise_locality_lost():
    a, b, ra, rb = _two_rings()
    try:
        plan = _toy_plan()
        bufs = [np.zeros(bk.size, np.float32) for bk in plan.buckets]
        ra.configure("fp32", plan, gen=1)
        ra.abort("drill")
        with pytest.raises(LocalityLostError, match="drill"):
            ra.allreduce(0, bufs, timeout=5)
        # peer_lost poisons ONLY an active ring
        ra.deactivate()
        ra.peer_lost(1)
        ra.configure("fp32", plan, gen=2)    # clears the poison
        ra.peer_lost(1)
        with pytest.raises(LocalityLostError, match="locality 1 died"):
            ra.allreduce(0, bufs, timeout=5)
    finally:
        ra.deactivate(), rb.deactivate()
        a.close(), b.close()


def test_ring_times_out_on_silent_peer():
    a, b, ra, rb = _two_rings()
    try:
        plan = _toy_plan()
        ra.configure("fp32", plan, gen=1)
        rb.configure("fp32", plan, gen=1)    # registered but never sends
        bufs = [np.zeros(bk.size, np.float32) for bk in plan.buckets]
        with pytest.raises(TimeoutError, match="segment"):
            ra.allreduce(0, bufs, timeout=0.4)
    finally:
        ra.deactivate(), rb.deactivate()
        a.close(), b.close()


# -- batch sharding & plan validation -----------------------------------------

def test_shard_batch_contiguous_rows_and_validation():
    batch = {"x": np.arange(24).reshape(6, 4), "y": np.arange(6)}
    parts = [shard_batch(batch, s, 3) for s in range(3)]
    np.testing.assert_array_equal(
        np.concatenate([p["x"] for p in parts]), batch["x"])
    np.testing.assert_array_equal(parts[1]["y"], batch["y"][2:4])
    with pytest.raises(ValueError, match="divisible"):
        shard_batch(batch, 0, 4)


def test_plan_ddp_validation_errors():
    with pytest.raises(ValueError, match="exclusive"):
        _plan(spmd=True, localities=2).compile()
    with pytest.raises(ValueError, match="grad_codec"):
        _plan(grad_codec="fp16").compile()
    with pytest.raises(ValueError, match="multiple of localities"):
        _plan(localities=2, ddp_shards=3).compile()
    with pytest.raises(ValueError, match="divisible"):
        _plan(ddp_shards=3).compile()        # batch=4, shards=3


def test_make_ddp_step_rejects_unsupported_strategies():
    with pytest.raises(ValueError, match="zero1"):
        steps_lib.make_ddp_step(plan=_plan(strategy=Strategy(name="zero1")))
    with pytest.raises(ValueError, match="grad_accum"):
        steps_lib.make_ddp_step(
            plan=_plan(strategy=Strategy(name="phylanx", grad_accum=2)))


def test_onebit_wire_is_exact_packed_size_for_real_model():
    """Satellite 3 (unit half): for the real test model's gradient plan,
    onebit wire bytes == 1 bit/elem + one f32 scale per 1024 elems,
    EXACTLY - and <= 1/16 of the fp32 wire."""
    step = steps_lib.make_ddp_step(
        shape={"seq_len": 16, "global_batch": 2, "kind": "train"},
        plan=_plan())
    gplan = step.grad_plan
    ob, fp = OneBitCodec().wire_bytes(gplan), Fp32Codec().wire_bytes(gplan)
    expect = sum(bk.size // 8 + 4 * (bk.size // compression.ROW)
                 for bk in gplan.buckets)
    assert ob == expect
    assert 16 * ob <= fp


# -- multi-process drills -----------------------------------------------------

@pytest.mark.multiproc
def test_ddp_fp32_two_localities_bit_identical_to_single():
    """Satellite 2a + 3: with the fp32 codec, a 2-locality DDP run over
    real processes is BIT-IDENTICAL in loss to a single-process run over
    the same 2 batch shards, and the driver's grad_wire_bytes counter is
    EXACTLY steps * (W-1) * codec_bytes."""
    steps = 6
    kw = dict(steps=steps, log_every=2, verbose=False)
    with _plan(ddp_shards=2).compile() as single:
        ref = single.train(**kw)
    with _plan(localities=2, ddp_shards=2).compile() as multi:
        out = multi.train(**kw)
    assert [float(x) for x in out["losses"]] == \
           [float(x) for x in ref["losses"]]
    assert float(out["final_loss"]) == float(ref["final_loss"])
    assert out["codec_bytes"] == ref["codec_bytes"]
    assert ref["grad_wire_bytes"] == 0            # world 1: nothing sent
    assert out["grad_wire_bytes"] == steps * 1 * out["codec_bytes"]


@pytest.mark.multiproc
def test_ddp_onebit_two_localities_converges_with_exact_wire():
    """Satellite 2b + 3: onebit over 2 real processes converges to
    within tolerance of the fp32 reference over 50 steps, with the wire
    EXACTLY the packed size and <= 1/16 of fp32."""
    steps = 50
    kw = dict(steps=steps, log_every=10, verbose=False)
    with _plan(ddp_shards=2).compile() as single:
        ref = single.train(**kw)
    with _plan(localities=2, ddp_shards=2,
               grad_codec="onebit").compile() as multi:
        out = multi.train(**kw)
    assert np.isfinite(out["final_loss"])
    # measured gap at 50 steps is ~0.08; 0.3 bounds run-to-run slack
    assert abs(out["final_loss"] - ref["final_loss"]) < 0.3
    assert out["grad_wire_bytes"] == steps * 1 * out["codec_bytes"]
    assert 16 * out["codec_bytes"] <= ref["codec_bytes"]


@pytest.mark.multiproc
def test_ddp_kill_mid_allreduce_aborts_cleanly():
    """Satellite 4: SIGKILL a worker mid-run - the survivors must abort
    the step with LocalityLostError (no hang) and the session must still
    close cleanly."""
    t0 = time.time()
    with _plan(batch=6, localities=3, ddp_shards=3).compile() as s:
        with pytest.raises(LocalityLostError):
            s.train(steps=30, kill_locality_at_step=3, log_every=10,
                    verbose=False)
    assert time.time() - t0 < 120          # abort, never hang
