"""MoE routing: dispatch-engine equivalence, capacity semantics, EP math."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sharding import init_params
from repro.models import moe


def _params(d=32, ff=64, E=4, key=jax.random.PRNGKey(0)):
    return init_params(moe.moe_specs(d, ff, E), key)


def test_sort_and_einsum_dispatch_agree_without_drops():
    """With capacity ample enough that nothing drops, both engines compute
    the same function."""
    d, ff, E, k = 32, 64, 4, 2
    p = _params(d, ff, E)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, d)) * 0.5
    y1, a1 = moe.apply_moe(x, p, top_k=k, group_size=32, cap_factor=8.0,
                           dispatch="einsum")
    y2, a2 = moe.apply_moe(x, p, top_k=k, group_size=32, cap_factor=8.0,
                           dispatch="sort")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-3,
                               atol=2e-4)
    np.testing.assert_allclose(float(a1), float(a2), rtol=1e-5)


def test_dense_equivalence_with_full_capacity_topE():
    """top_k == E with ample capacity == dense mixture over all experts."""
    d, ff, E = 16, 32, 4
    p = _params(d, ff, E, jax.random.PRNGKey(2))
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 8, d)) * 0.5
    y, _ = moe.apply_moe(x, p, top_k=E, group_size=8, cap_factor=E * 2.0,
                         dispatch="einsum")
    # dense reference
    logits = x.reshape(-1, d) @ p["router"]
    w = jax.nn.softmax(logits, -1)
    xin = jnp.broadcast_to(x.reshape(-1, d)[None], (E, 8, d))
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xin, p["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", xin, p["w_up"])
    yo = jnp.einsum("ecf,efd->ecd", h, p["w_down"])
    want = jnp.einsum("te,etd->td", w, yo).reshape(1, 8, d)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), rtol=2e-3,
                               atol=2e-4)


def test_capacity_drops_tokens_not_crash():
    d, ff, E = 16, 32, 4
    p = _params(d, ff, E, jax.random.PRNGKey(4))
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 64, d))
    # capacity factor tiny -> most tokens dropped, output finite & small
    y, aux = moe.apply_moe(x, p, top_k=2, group_size=64, cap_factor=0.1,
                           dispatch="einsum")
    assert bool(jnp.isfinite(y).all())
    assert float(jnp.abs(y).mean()) < float(jnp.abs(x).mean()) * 10
    assert np.isfinite(float(aux))


def test_capacity_rounding():
    assert moe.capacity(512, 8, 2, 1.25) == 160
    assert moe.capacity(512, 8, 2, 1.25) % 8 == 0
    assert moe.capacity(8, 64, 1, 1.0) >= 8  # floor


def test_router_weights_normalized():
    w = jax.random.normal(jax.random.PRNGKey(6), (16, 8))
    x = jax.random.normal(jax.random.PRNGKey(7), (32, 16))
    gw, gi, aux = moe.router_probs(x, w, 2)
    np.testing.assert_allclose(np.asarray(gw.sum(-1)), 1.0, rtol=1e-5)
    assert int(gi.max()) < 8 and int(gi.min()) >= 0
    # top-k ids are distinct per token
    assert bool((gi[:, 0] != gi[:, 1]).all())


def test_aux_loss_penalizes_imbalance():
    d, E = 8, 4
    x = jnp.abs(jax.random.normal(jax.random.PRNGKey(8), (256, d))) + 0.1
    # balanced router: expert e keyed to feature e -> ~uniform assignment
    w_bal = jnp.zeros((d, E))
    for e in range(E):
        w_bal = w_bal.at[e, e].set(10.0)
    _, gi, aux_b = moe.router_probs(x, w_bal, 1)
    counts = jnp.bincount(gi[:, 0], length=E)
    assert int(counts.min()) > 0          # genuinely spread
    # router that always picks expert 0 (positive inputs) -> aux near E
    w_collapse = jnp.zeros((d, E)).at[:, 0].set(10.0)
    _, _, aux_c = moe.router_probs(x, w_collapse, 1)
    assert float(aux_c) > float(aux_b) * 1.5
    assert float(aux_c) > 0.9 * E  # collapsed ~ E
