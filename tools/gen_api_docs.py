"""Generate docs/API.md from the public runtime API's docstrings.

The docstring audit (DESIGN.md §9 satellite) made every public symbol of
the futurized runtime, the frontend, and the multi-locality runtime
carry args/returns/raises; this script turns those docstrings into one
browsable reference so the docs can never drift silently - CI runs
``--check`` and fails when docs/API.md is stale.

    PYTHONPATH=src python tools/gen_api_docs.py            # regenerate
    PYTHONPATH=src python tools/gen_api_docs.py --check    # CI gate
"""
import argparse
import importlib
import inspect
import sys
import textwrap
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

# (module, [symbol or "Class.method" ...]); order is the document order
API = [
    ("repro.core.futures", [
        "Lane", "PhyFuture",
        "PhyFuture.result", "PhyFuture.cancel", "PhyFuture.exception",
        "PhyFuture.add_done_callback", "PhyFuture.set_result",
        "PhyFuture.set_exception",
        "FuturizedGraph",
        "FuturizedGraph.defer", "FuturizedGraph.immediate",
        "FuturizedGraph.promise", "FuturizedGraph.when_all",
        "FuturizedGraph.when_any", "FuturizedGraph.tree_join",
        "FuturizedGraph.gather", "FuturizedGraph.barrier",
        "FuturizedGraph.stats", "FuturizedGraph.shutdown",
        "FuturizedGraph.add_trace_hook",
        "FuturizedGraph.record_serve",
        "RuntimeStats", "Pipeline", "hist_labels",
    ]),
    ("repro.core.paging", [
        "PageError",
        "PagePool", "PagePool.alloc", "PagePool.free",
        "PagePool.write", "PagePool.read", "PagePool.owners",
        "PagePool.counters",
        "InferenceCache", "InferenceCache.put", "InferenceCache.get",
        "InferenceCache.drop", "InferenceCache.counters",
    ]),
    ("repro.frontend", [
        "Plan", "Plan.compile",
        "Session", "Session.train", "Session.serve",
        "Session.serve_stream", "Session.dryrun",
        "Session.close", "Session.stats", "Session.kill_locality",
        "Session.add_locality", "Session.lint",
        "futurize", "tracing", "Trace", "serve_flags",
    ]),
    ("repro.frontend.gateway", [
        "RequestQueue", "RequestQueue.submit", "RequestQueue.close",
        "RequestHandle", "RequestHandle.result", "RequestHandle.cancel",
        "Gateway", "Gateway.run",
        "RequestRejected", "DeadlineExpired",
    ]),
    ("repro.analysis.lint", [
        "Finding", "LintGraph",
        "LintGraph.add", "LintGraph.mark_forced",
        "LintGraph.from_trace", "LintGraph.from_graph",
        "lint",
    ]),
    ("repro.analysis.sanitize", [
        "Diagnostic", "DeadlockError",
        "Sanitizer", "Sanitizer.record", "Sanitizer.diagnostics",
        "Sanitizer.clear",
        "get", "active", "enabled", "config",
        "find_cycle", "thread_stacks",
    ]),
    ("repro.analysis.trace_builders", [
        "train_trace", "serve_trace", "gateway_trace", "step_contract",
        "plan_traces",
    ]),
    ("repro.distrib", [
        "Endpoint", "Endpoint.register", "Endpoint.connect",
        "Endpoint.request", "Endpoint.post", "Endpoint.close",
        "raw_request",
        "ObjectDirectory", "ObjectDirectory.put", "ObjectDirectory.fetch",
        "ObjectDirectory.free", "ObjectDirectory.rebalance",
        "ObjectDirectory.audit", "RemoteRef", "rebalance_plan",
        "DistributedGraph", "DistributedGraph.defer",
        "DistributedGraph.add_locality", "DistributedGraph.rebalance",
        "DistributedGraph.replicate", "DistributedGraph.cancel",
        "DistributedGraph.fetch", "DistributedGraph.stats",
        "DistributedGraph.remote_stats", "DistributedGraph.barrier",
        "DistributedGraph.shutdown", "DistributedGraph.spmd_train",
        "DistributedGraph.spmd_entry_futures",
        "DistributedGraph.wait_spmd_done",
        "DistributedGraph.account_ckpt_leaf_bytes",
        "DistributedGraph.ddp_train", "DistributedGraph.wait_ddp_done",
        "DistributedGraph.ddp_abort",
        "DistributedGraph.account_grad_wire_bytes",
        "Locality", "LocalityGroup", "LocalityGroup.kill",
        "LocalityGroup.add_worker", "worker_main", "join_locality",
    ]),
    ("repro.distrib.collectives", [
        "GradCodec", "GradCodec.reset", "GradCodec.encode",
        "GradCodec.decode", "GradCodec.wire_bytes",
        "Fp32Codec", "OneBitCodec", "get_codec",
        "RingAllReduce", "RingAllReduce.configure",
        "RingAllReduce.allreduce", "RingAllReduce.abort",
        "RingAllReduce.peer_lost", "RingAllReduce.deactivate",
    ]),
    ("repro.frontend.spmd", [
        "shadow_train",
    ]),
    ("repro.frontend.ddp", [
        "DDPEngine", "DDPEngine.init", "DDPEngine.train_step",
        "ddp_shadow_train", "shard_batch",
    ]),
    ("repro.data.pipeline", [
        "Prefetcher", "Prefetcher.schedule", "Prefetcher.get_future",
        "stream_for",
    ]),
    ("repro.checkpoint.checkpoint", [
        "CheckpointManager", "CheckpointManager.save",
        "CheckpointManager.restore", "CheckpointManager.wait",
        "CheckpointManager.ranks", "CheckpointManager.close",
    ]),
    ("repro.checkpoint.format", [
        "CheckpointCorruptError", "assign_shards", "save_shard",
        "read_shard", "read_shard_segments", "assemble_leaf",
        "build_manifest", "commit_manifest",
        "load_manifest", "shard_filename", "writer_rank",
    ]),
    ("repro.checkpoint.spmd", [
        "is_multiprocess", "persistence_mesh", "persistence_sharding",
        "global_view", "addressable_segments", "collect_segments",
        "write_spmd_shard", "device_put_maybe_global",
    ]),
]

HEADER = """\
# phyrax public API

*Generated by `tools/gen_api_docs.py` from source docstrings - do not
edit by hand; regenerate with*
`PYTHONPATH=src python tools/gen_api_docs.py`.
*CI (`.github/workflows/ci.yml`, multiproc job) fails when this file is
stale.*
"""


def _signature(obj) -> str:
    try:
        return str(inspect.signature(obj))
    except (TypeError, ValueError):
        return ""


def _doc(obj) -> str:
    doc = inspect.getdoc(obj)
    return doc.strip() if doc else "*(undocumented)*"


def render() -> str:
    out = [HEADER]
    for mod_name, symbols in API:
        mod = importlib.import_module(mod_name)
        out.append(f"\n## `{mod_name}`\n")
        mod_doc = inspect.getdoc(mod)
        if mod_doc:
            out.append(mod_doc.split("\n\n")[0] + "\n")
        for sym in symbols:
            parts = sym.split(".")
            obj = mod
            for p in parts:
                obj = getattr(obj, p)
            title = f"`{sym}`"
            if inspect.isclass(obj):
                out.append(f"### {title}\n")
                sig = _signature(obj)
                if sig:
                    out.append(f"```python\n{sym}{sig}\n```\n")
            else:
                depth = "####" if len(parts) > 1 else "###"
                out.append(f"{depth} {title}\n")
                sig = _signature(obj)
                if sig:
                    out.append(f"```python\n{parts[-1]}{sig}\n```\n")
            out.append(textwrap.dedent(_doc(obj)) + "\n")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="exit 1 if docs/API.md is stale instead of writing")
    args = ap.parse_args()
    target = ROOT / "docs" / "API.md"
    text = render()
    if args.check:
        current = target.read_text() if target.exists() else ""
        if current != text:
            print(f"STALE: {target} does not match the docstrings; "
                  f"regenerate with PYTHONPATH=src python "
                  f"tools/gen_api_docs.py")
            raise SystemExit(1)
        print(f"OK: {target} is current "
              f"({sum(len(s) for _, s in API)} symbols)")
        return
    target.parent.mkdir(parents=True, exist_ok=True)
    target.write_text(text)
    print(f"wrote {target} ({len(text.splitlines())} lines, "
          f"{sum(len(s) for _, s in API)} symbols)")


if __name__ == "__main__":
    main()
