"""Fail CI when DESIGN.md cross-references drift.

Every ``DESIGN.md#<anchor>`` markdown link and every textual
``DESIGN.md §N`` section reference found in README.md and docs/API.md -
plus every ``§N`` mention inside DESIGN.md itself, and the ``DESIGN.md
§N`` pointers embedded in source docstrings of the phylint tooling and
the CI workflow - must resolve to a real DESIGN.md heading.  Run by the
``docs`` CI job next to the generated-API staleness gate, so renaming or
deleting a DESIGN.md section without fixing its referrers fails the
build.

    python tools/check_doc_anchors.py
"""
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

# files scanned for references into DESIGN.md; the source files carry
# rule-catalogue pointers ("DESIGN.md §12") in their docstrings and
# diagnostics, and must not rot when sections are renumbered
REFERRERS = [
    "README.md",
    "docs/API.md",
    "DESIGN.md",
    "src/repro/analysis/__init__.py",
    "src/repro/analysis/lint.py",
    "src/repro/analysis/sanitize.py",
    "tools/phylint.py",
    ".github/workflows/ci.yml",
]


def github_slug(heading: str) -> str:
    """GitHub's heading -> anchor rule: lowercase, drop everything but
    word characters / hyphens / spaces, then spaces -> hyphens."""
    s = heading.strip().lower()
    s = re.sub(r"[^\w\- ]", "", s)
    return s.replace(" ", "-")


def design_targets() -> tuple[set, set]:
    """(anchor slugs, §-section numbers) defined by DESIGN.md headings."""
    text = (ROOT / "DESIGN.md").read_text()
    headings = re.findall(r"^#{1,6}\s+(.+)$", text, re.M)
    slugs = {github_slug(h) for h in headings}
    sections = set(re.findall(r"§(\d+)", " ".join(headings)))
    return slugs, sections


def main() -> int:
    slugs, sections = design_targets()
    bad = []
    for name in REFERRERS:
        path = ROOT / name
        if not path.exists():
            bad.append(f"{name}: referenced file is missing")
            continue
        text = path.read_text()
        for m in re.finditer(r"DESIGN\.md#([A-Za-z0-9_\-]+)", text):
            if m.group(1) not in slugs:
                bad.append(f"{name}: dead anchor DESIGN.md#{m.group(1)}")
        pat = (r"§(\d+)" if name == "DESIGN.md"
               else r"DESIGN\.md\s+§(\d+)")
        for m in re.finditer(pat, text):
            if m.group(1) not in sections:
                bad.append(f"{name}: DESIGN.md §{m.group(1)} does not exist")
    if bad:
        print("DESIGN.md cross-reference check FAILED:")
        for b in bad:
            print(f"  {b}")
        return 1
    span = (f"§{min(sections, key=int)}-§{max(sections, key=int)}"
            if sections else "none")
    print(f"OK: {len(slugs)} anchors / sections {span} cover every "
          f"reference in {', '.join(REFERRERS)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
