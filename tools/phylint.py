"""phylint CLI: statically lint the execution trees of shipped configs.

Dryrun-traces every architecture in ``repro.configs`` (no devices, no
parameters - the builders in ``repro.analysis.trace_builders`` mirror the
host trees ``Session.train`` / ``Session.serve`` would build) and runs
the PHY001-PHY006 static passes (DESIGN.md §12) over each graph.  The
``phylint`` CI job runs it with ``--all-configs --strict`` so a config or
loop change that introduces a cycle, an orphaned promise, a lane
inversion, a dead node, or a donation-after-use hazard fails the build.

    python tools/phylint.py --all-configs --strict
    python tools/phylint.py --arch qwen3-4b --variant ddp
    python tools/phylint.py --arch qwen3-4b --variant serve   # gateway tree
    python tools/phylint.py --list-rules
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

#: Plan variants traced per architecture: standard single-locality
#: training, the serving trees (wave serve + the continuous-batching
#: gateway, DESIGN.md §14), fabric-DDP shadow, and SPMD shadow
#: (DESIGN.md §10-§11).  DDP/SPMD builders mirror the driver tree, so
#: localities=2 is representative.  ``workloads`` filters the
#: ``plan_traces`` output so no tree is linted twice across variants.
VARIANTS = {
    "standard": {"plan": dict(), "workloads": ("train", "step-contract")},
    "serve": {"plan": dict(),
              "workloads": ("serve", "gateway", "gateway-replicas")},
    "ddp": {"plan": dict(ddp=True, localities=2), "workloads": None},
    "spmd": {"plan": dict(spmd=True, localities=2), "workloads": None},
}


def iter_graphs(arch_ids, variants):
    from repro.analysis import plan_traces
    from repro.frontend.plan import Plan

    for aid in arch_ids:
        for vname in variants:
            spec = VARIANTS[vname]
            plan = Plan(arch=aid, tiny=True, **spec["plan"])
            keep = spec["workloads"]
            for wname, graph in plan_traces(plan).items():
                if keep is not None and wname not in keep:
                    continue
                yield f"{aid}/{vname}/{wname}", graph


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="phylint", description=__doc__.splitlines()[0])
    ap.add_argument("--all-configs", action="store_true",
                    help="lint every architecture in repro.configs")
    ap.add_argument("--arch", action="append", default=[],
                    help="lint one architecture id (repeatable)")
    ap.add_argument("--variant", action="append", default=[],
                    choices=sorted(VARIANTS),
                    help="restrict to plan variants (default: all)")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on any finding")
    ap.add_argument("--strict-lanes", action="store_true",
                    help="also flag the PREFETCH->COMPUTE feed edge "
                         "(PHY003 without the exemption)")
    ap.add_argument("--fanin-threshold", type=int, default=None,
                    help="override the PHY006 fan-in threshold")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalogue and exit")
    args = ap.parse_args(argv)

    from repro.analysis import lint as lint_mod
    from repro.analysis.sanitize import DYNAMIC_RULES

    if args.list_rules:
        for rid, desc in sorted({**lint_mod.STATIC_RULES,
                                 **DYNAMIC_RULES}.items()):
            print(f"{rid}  {desc}")
        return 0

    from repro.configs import ARCH_IDS

    arch_ids = list(ARCH_IDS) if args.all_configs or not args.arch \
        else args.arch
    unknown = [a for a in arch_ids if a not in ARCH_IDS]
    if unknown:
        ap.error(f"unknown arch id(s): {', '.join(unknown)} "
                 f"(known: {', '.join(ARCH_IDS)})")
    variants = args.variant or sorted(VARIANTS)

    kwargs = {"strict_lanes": args.strict_lanes}
    if args.fanin_threshold is not None:
        kwargs["fanin_threshold"] = args.fanin_threshold

    graphs = findings = 0
    for label, graph in iter_graphs(arch_ids, variants):
        graphs += 1
        found = lint_mod.lint(graph, **kwargs)
        findings += len(found)
        for f in found:
            where = f" [{', '.join(f.nodes)}]" if f.nodes else ""
            hint = f"  ({f.src})" if f.src else ""
            print(f"{label}: {f.rule}: {f.message}{where}{hint}")
    status = "clean" if findings == 0 else f"{findings} finding(s)"
    print(f"phylint: {graphs} graph(s) over {len(arch_ids)} config(s): "
          f"{status}")
    return 1 if (findings and args.strict) else 0


if __name__ == "__main__":
    sys.exit(main())
